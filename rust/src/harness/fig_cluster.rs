//! Cluster scaling curves (`coroamu report --cluster`): the
//! `sim::cluster` axis — 1/2/4/8 cores contending on ONE shared far
//! fabric, × fabric model × scheduler policy, at the paper's
//! high-disaggregation latency point. This is the multi-requester
//! companion to the fabric sweep: instead of asking *how one core's
//! fabric behaves*, it asks where aggregate throughput stops scaling as
//! compute nodes pile onto a shared memory pool, and which coroutine
//! scheduler degrades most gracefully once the fabric saturates.
//!
//! The far wire bandwidth is raised well above the single-core demand
//! ([`FAR_BW_BYTES_PER_CYCLE`]) so the *fixed-delay* fabric models an
//! overprovisioned pool — pure latency, no structural bottleneck — and
//! scales near-linearly. The *queued* fabric keeps its finite request
//! queue and congestion, so its aggregate throughput saturates as cores
//! grow; the gap between the two curves is the cost of the shared
//! bottleneck itself (pinned by the acceptance test below).
//!
//! Core count, fabric, policy and latency are all simulate-time knobs,
//! so the whole matrix compiles each kernel exactly once and builds
//! each dataset exactly once.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::sim::fabric::{FabricKind, DEFAULT_QUEUE_DEPTH};
use crate::sim::sched::SchedPolicyKind;
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

/// The far-latency point of the sweep (the paper's high-disaggregation
/// setting, matching the fabric sweep).
pub const LATENCY_NS: f64 = 800.0;

/// Far wire bandwidth for the cluster session, bytes/cycle. High enough
/// that the fixed-delay pool never serializes on the wire even at eight
/// cores — saturation in the tables is then attributable to the queued
/// fabric's finite depth + congestion, not to a shared-wire artifact.
pub const FAR_BW_BYTES_PER_CYCLE: f64 = 256.0;

/// The swept cluster sizes.
pub const CORES: [u32; 4] = [1, 2, 4, 8];

/// The two fabric endpoints of the scaling story: an overprovisioned
/// pool (pure latency) vs a depth-limited, congested link.
pub fn fabrics() -> [FabricKind; 2] {
    [FabricKind::FixedDelay, FabricKind::Queued { depth: DEFAULT_QUEUE_DEPTH }]
}

/// The policy axis: the paper's native arrival order vs the
/// latency-aware dynamic policy (the two ends of the `sim::sched`
/// static-vs-dynamic spectrum).
pub fn policies() -> [SchedPolicyKind; 2] {
    [SchedPolicyKind::ArrivalOrder, SchedPolicyKind::LatencyAware]
}

/// The irregular subset the cluster axis discriminates on (same
/// rationale as the fabric sweep; far-bound scatter + pointer chasing).
pub const DEFAULT_BENCHES: [&str; 2] = ["gups", "bfs"];

fn benches(opts: &FigOpts) -> Vec<String> {
    if opts.only.is_empty() {
        DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect()
    } else {
        opts.only.clone()
    }
}

/// The session config: NH-G with the overprovisioned far wire.
pub fn session_cfg() -> SimConfig {
    let mut cfg = SimConfig::nh_g();
    cfg.mem.far_bw_bytes_per_cycle = FAR_BW_BYTES_PER_CYCLE;
    cfg
}

fn key(cores: u32, f: FabricKind, p: SchedPolicyKind) -> String {
    format!("{cores}c/{}/{}", f.label(), p.label())
}

/// The request matrix: CoroAMU-Full per (cores × fabric × policy ×
/// bench), every knob simulate-time.
pub fn requests(opts: &FigOpts) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for &n in &CORES {
        for f in fabrics() {
            for p in policies() {
                for b in benches(opts) {
                    matrix.push(
                        RunRequest::new(b, Variant::CoroAmuFull)
                            .scale(opts.scale)
                            .seed(opts.seed)
                            .latency_ns(LATENCY_NS)
                            .fabric(f)
                            .policy(p)
                            .cores(n)
                            .key(key(n, f, p)),
                    );
                }
            }
        }
    }
    matrix
}

/// Aggregate decoded throughput of one run: total dynamic instructions
/// over the cluster makespan (instructions/cycle summed across cores).
fn agg_ipc(st: &crate::sim::RunStats) -> f64 {
    st.dyn_instrs as f64 / st.cycles.max(1) as f64
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let rs = grid::fetch(session_cfg(), &requests(opts), opts.threads)?;
    let benches = benches(opts);
    let mut tables = Vec::new();

    // Geomean-over-benches aggregate-throughput scaling of (cores,
    // fabric, policy) relative to the same (fabric, policy) at 1 core.
    let scaling = |n: u32, f: FabricKind, p: SchedPolicyKind| -> f64 {
        let per_bench: Vec<f64> = benches
            .iter()
            .map(|b| {
                let base = lookup(&rs, b, Variant::CoroAmuFull, &key(1, f, p)).unwrap();
                let at_n = lookup(&rs, b, Variant::CoroAmuFull, &key(n, f, p)).unwrap();
                agg_ipc(&at_n.stats) / agg_ipc(&base.stats)
            })
            .collect();
        geomean(&per_bench)
    };

    // T1: the scaling curves — aggregate throughput vs cores, one row
    // per (fabric, policy). Linear = the core count; the queued rows
    // flatten where the shared fabric saturates.
    let mut cols: Vec<String> = vec!["fabric".into(), "policy".into()];
    cols.extend(CORES.iter().map(|n| format!("{n} cores")));
    let mut t1 = Table::new(
        format!(
            "Cluster scaling: aggregate throughput vs 1 core ({LATENCY_NS} ns, {} B/cyc wire)",
            FAR_BW_BYTES_PER_CYCLE
        ),
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for f in fabrics() {
        for p in policies() {
            let mut row = vec![f.label(), p.label()];
            for &n in &CORES {
                row.push(speedup(scaling(n, f, p)));
            }
            t1.row(row);
        }
    }
    tables.push(t1);

    // T2: what the shared fabric saw (first bench, arrival order) —
    // where the queue fills, the tail fattens, and fairness drifts.
    if let Some(b) = benches.first() {
        let mut t2 = Table::new(
            format!("Shared-fabric saturation ({b}, CoroAMU-Full/arrival, {LATENCY_NS} ns)"),
            &[
                "fabric",
                "cores",
                "makespan",
                "requests",
                "p50 lat",
                "p99 lat",
                "queue stalls",
                "fairness",
            ],
        );
        for f in fabrics() {
            for &n in &CORES {
                let st =
                    &lookup(&rs, b, Variant::CoroAmuFull, &key(n, f, SchedPolicyKind::ArrivalOrder))
                        .unwrap()
                        .stats;
                t2.row(vec![
                    f.label(),
                    n.to_string(),
                    st.cycles.to_string(),
                    st.fabric_requests.to_string(),
                    st.fabric_p50.to_string(),
                    st.fabric_p99.to_string(),
                    st.fabric_queue_stalls.to_string(),
                    if n == 1 { "-".into() } else { format!("{:.3}", st.cluster_fairness) },
                ]);
            }
        }
        tables.push(t2);
    }

    // T3: graceful degradation — per policy, how much of its own
    // overprovisioned-pool scaling survives the queued fabric at the
    // largest cluster. Higher = the scheduler copes better with a
    // saturated shared fabric.
    let max_cores = *CORES.last().unwrap();
    let mut t3 = Table::new(
        format!("Scheduler degradation under fabric saturation ({max_cores} cores)"),
        &["policy", "fixed scaling", "queued scaling", "retained"],
    );
    for p in policies() {
        let fixed = scaling(max_cores, FabricKind::FixedDelay, p);
        let queued = scaling(max_cores, FabricKind::Queued { depth: DEFAULT_QUEUE_DEPTH }, p);
        t3.row(vec![
            p.label(),
            speedup(fixed),
            speedup(queued),
            format!("{:.0}%", 100.0 * queued / fixed.max(1e-12)),
        ]);
    }
    tables.push(t3);

    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_the_cluster_axis() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let m = requests(&opts);
        // 4 core counts x 2 fabrics x 2 policies x 2 benches.
        assert_eq!(m.len(), 4 * 2 * 2 * 2);
        for &n in &CORES {
            assert!(
                m.iter().filter(|r| r.cores == Some(n)).count() == 2 * 2 * 2,
                "core count {n} missing from the matrix"
            );
        }
        assert!(m.iter().all(|r| r.latency_ns == Some(LATENCY_NS)));
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts).unwrap();
        assert_eq!(tables.len(), 3);
        let all: String = tables.iter().map(|t| t.render()).collect();
        assert!(all.contains("8 cores"), "{all}");
        assert!(all.contains("queued:"), "{all}");
        assert!(all.contains("fairness"), "{all}");
        assert!(all.contains("retained"), "{all}");
    }

    /// The acceptance criterion: on the overprovisioned fixed-delay pool
    /// aggregate throughput scales ~linearly with cores, while the
    /// depth-limited queued fabric saturates — its 8-core scaling is
    /// sub-linear and falls clearly short of fixed-delay's. Deterministic
    /// seeds make this a regression pin, not a flaky perf assertion.
    #[test]
    fn queued_fabric_saturates_while_fixed_delay_scales() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let rs = crate::engine::Engine::new(session_cfg()).sweep(&requests(&opts), opts.threads).unwrap();
        let p = SchedPolicyKind::ArrivalOrder;
        let queued = FabricKind::Queued { depth: DEFAULT_QUEUE_DEPTH };
        let ipc = |n: u32, f: FabricKind| {
            let r = lookup(&rs, "gups", Variant::CoroAmuFull, &key(n, f, p)).unwrap();
            agg_ipc(&r.stats)
        };
        let fixed_s8 = ipc(8, FabricKind::FixedDelay) / ipc(1, FabricKind::FixedDelay);
        let queued_s8 = ipc(8, queued) / ipc(1, queued);
        assert!(
            fixed_s8 > 5.0,
            "overprovisioned fixed-delay pool should scale near-linearly to 8 cores, got {fixed_s8:.2}x"
        );
        assert!(
            queued_s8 < 0.75 * 8.0,
            "queued fabric must saturate sub-linearly at 8 cores, got {queued_s8:.2}x"
        );
        assert!(
            queued_s8 < fixed_s8,
            "queued ({queued_s8:.2}x) must fall short of fixed-delay ({fixed_s8:.2}x)"
        );
        // The saturation is visible in the fabric stats too: the shared
        // queue backpressures harder with more requesters.
        let stalls = |n: u32| {
            lookup(&rs, "gups", Variant::CoroAmuFull, &key(n, queued, p))
                .unwrap()
                .stats
                .fabric_queue_stalls
        };
        assert!(
            stalls(8) > stalls(1),
            "8 requesters must stall more than 1 ({} vs {})",
            stalls(8),
            stalls(1)
        );
    }
}
