//! Fault-injection chaos tables (`coroamu report --faults`): the
//! `sim::faults` axis — fault intensity × scheduler policies at the
//! high-latency disaggregation point. Where `fig_fabric` sweeps how the
//! fabric *behaves*, this sweeps how it *fails* (NACK storms, latency
//! spikes, degradation windows, blackouts) and shows how much chaos each
//! resume policy tolerates: `LatencyAware`/`BatchedWakeup` re-rank
//! coroutines as completion times scatter, while `Fifo`/`ArrivalOrder`
//! eat the head-of-line blocking that retries and slow paths create.
//! Every row carries a fault-free differential column, so the overhead
//! of chaos (not just the absolute speedup) is explicit.
//!
//! Faults, policy and latency are all simulate-time knobs, so the whole
//! matrix compiles each (benchmark, variant) kernel exactly once and
//! builds each dataset exactly once.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::sim::faults::FaultConfig;
use crate::sim::sched::SchedPolicyKind;
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

/// The far-latency point the chaos axis is measured at: the paper's
/// high-disaggregation setting, where far-request stalls dominate and
/// fault handling is on the critical path.
pub const LATENCY_NS: f64 = 800.0;

/// The irregular subset the chaos axis discriminates on (same set as the
/// fabric sweep): random scatter (gups), pointer chasing (bfs) and
/// dependent hashing (hj).
pub const DEFAULT_BENCHES: [&str; 3] = ["gups", "bfs", "hj"];

fn benches(opts: &FigOpts) -> Vec<String> {
    if opts.only.is_empty() {
        DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect()
    } else {
        opts.only.clone()
    }
}

/// The swept fault intensities: the two presets, or a single spec when
/// the CLI restricts the axis (`report --faults heavy`). The fault-free
/// baseline is always run alongside (the differential column).
pub fn intensities(only: Option<FaultConfig>) -> Vec<FaultConfig> {
    match only {
        Some(f) => vec![f],
        None => vec![FaultConfig::mild(), FaultConfig::heavy()],
    }
}

/// The request matrix: per bench a fault-free serial baseline, then per
/// (intensity ∪ {off}) × policy a CoroAMU-Full run. The `off` column is
/// the fault-free differential every chaos row is compared against.
pub fn requests(opts: &FigOpts, specs: &[FaultConfig]) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for b in benches(opts) {
        matrix.push(
            RunRequest::new(b.clone(), Variant::Serial)
                .scale(opts.scale)
                .seed(opts.seed)
                .latency_ns(LATENCY_NS)
                .key("off"),
        );
        for spec in std::iter::once(FaultConfig::off()).chain(specs.iter().copied()) {
            for p in SchedPolicyKind::ALL {
                matrix.push(
                    RunRequest::new(b.clone(), Variant::CoroAmuFull)
                        .scale(opts.scale)
                        .seed(opts.seed)
                        .latency_ns(LATENCY_NS)
                        .faults(spec)
                        .policy(p)
                        .key(full_key(&spec, p)),
                );
            }
        }
    }
    matrix
}

/// Key of the CoroAMU-Full run for (fault spec, policy).
fn full_key(f: &FaultConfig, p: SchedPolicyKind) -> String {
    format!("{}/{}", f.label(), p.label())
}

pub fn run(opts: &FigOpts, only: Option<FaultConfig>) -> Result<Vec<Table>> {
    let specs = intensities(only);
    let rs = grid::fetch(SimConfig::nh_g(), &requests(opts, &specs), opts.threads)?;
    let benches = benches(opts);
    let arrival = SchedPolicyKind::ArrivalOrder;
    let mut tables = Vec::new();

    // T1: policy × intensity — CoroAMU-Full speedup vs the fault-free
    // serial baseline per bench, with the fault-free differential:
    // geomean slowdown of the chaos row against the same policy's
    // fault-free run (the cost of surviving the faults).
    let mut cols: Vec<String> = vec!["faults".into(), "policy".into()];
    cols.extend(benches.iter().cloned());
    cols.push("geomean".into());
    cols.push("vs fault-free".into());
    let mut t1 = Table::new(
        format!("Policy × fault intensity: CoroAMU-Full speedup vs serial ({LATENCY_NS} ns)"),
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let off = FaultConfig::off();
    for spec in std::iter::once(&off).chain(specs.iter()) {
        for p in SchedPolicyKind::ALL {
            let mut row = vec![spec.label(), p.label()];
            let mut sp = Vec::new();
            let mut overhead = Vec::new();
            for b in &benches {
                let serial = lookup(&rs, b, Variant::Serial, "off").unwrap().stats.cycles as f64;
                let full =
                    lookup(&rs, b, Variant::CoroAmuFull, &full_key(spec, p)).unwrap().stats.cycles
                        as f64;
                let clean =
                    lookup(&rs, b, Variant::CoroAmuFull, &full_key(&off, p)).unwrap().stats.cycles
                        as f64;
                sp.push(serial / full);
                overhead.push(full / clean);
                row.push(speedup(serial / full));
            }
            row.push(speedup(geomean(&sp)));
            let oh = geomean(&overhead);
            row.push(if spec.enabled() { format!("{:+.1}%", 100.0 * (oh - 1.0)) } else { "-".into() });
            t1.row(row);
        }
    }
    tables.push(t1);

    // T2: what each intensity actually did to the requests and how the
    // resilience machinery absorbed it (first bench, arrival order).
    if let Some(b) = benches.first() {
        let mut t2 = Table::new(
            format!("Resilience behavior ({b}, CoroAMU-Full/arrival, {LATENCY_NS} ns)"),
            &[
                "faults",
                "nacks",
                "retries",
                "backoff cycles",
                "timeouts",
                "slow-path",
                "degraded cycles",
                "max stall",
            ],
        );
        for spec in std::iter::once(&off).chain(specs.iter()) {
            let st = &lookup(&rs, b, Variant::CoroAmuFull, &full_key(spec, arrival))
                .unwrap()
                .stats;
            t2.row(vec![
                spec.label(),
                st.fault_nacks.to_string(),
                st.fault_retries.to_string(),
                st.fault_retry_cycles.to_string(),
                st.fault_timeouts.to_string(),
                st.fault_slow_path.to_string(),
                st.fault_degraded_cycles.to_string(),
                st.fault_max_stall.to_string(),
            ]);
        }
        tables.push(t2);
    }

    // T3: chaos tolerance of dynamic vs static resume order — per
    // (intensity, bench), cycles under arrival order against the dynamic
    // policies, with the winner's margin. Retries and slow paths scatter
    // completion times far beyond what any fabric backend does, which is
    // exactly the regime the dynamic policies were built for.
    let mut t3 = Table::new(
        format!("Dynamic vs static resume order under chaos ({LATENCY_NS} ns)"),
        &["faults", "bench", "arrival", "latency-aware", "batched", "best dynamic", "gain"],
    );
    for spec in specs.iter() {
        for b in &benches {
            let cyc = |p: SchedPolicyKind| {
                lookup(&rs, b, Variant::CoroAmuFull, &full_key(spec, p)).unwrap().stats.cycles
            };
            let base = cyc(arrival);
            let la = cyc(SchedPolicyKind::LatencyAware);
            let bw = cyc(SchedPolicyKind::BatchedWakeup(crate::sim::sched::DEFAULT_BATCH));
            let (best_label, best) = if la <= bw { ("latency", la) } else { ("batched", bw) };
            let gain = 100.0 * (base as f64 - best as f64) / base as f64;
            t3.row(vec![
                spec.label(),
                b.clone(),
                base.to_string(),
                la.to_string(),
                bw.to_string(),
                best_label.into(),
                format!("{gain:+.2}%"),
            ]);
        }
    }
    tables.push(t3);

    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_the_acceptance_axis() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let specs = intensities(None);
        let m = requests(&opts, &specs);
        // 3 benches x (serial + (off + mild + heavy) x 4 policies).
        assert_eq!(m.len(), 3 * (1 + 3 * 4));
        // Every chaos run names its spec; the fault-free differential
        // runs are present for every policy.
        for spec in &specs {
            assert!(
                m.iter().filter(|r| r.faults == Some(*spec)).count() == 3 * 4,
                "{} missing from the matrix",
                spec.label()
            );
        }
        assert_eq!(m.iter().filter(|r| r.faults == Some(FaultConfig::off())).count(), 3 * 4);
        // Restricting the axis keeps one intensity (plus the baseline).
        let one = requests(&opts, &intensities(Some(FaultConfig::blackout())));
        assert_eq!(one.len(), 3 * (1 + 2 * 4));
        assert!(one
            .iter()
            .all(|r| r.faults.is_none()
                || r.faults == Some(FaultConfig::off())
                || r.faults == Some(FaultConfig::blackout())));
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, None).unwrap();
        // policy x intensity + resilience behavior + dynamic-vs-static.
        assert_eq!(tables.len(), 3);
        let all: String = tables.iter().map(|t| t.render()).collect();
        for spec in ["off", "mild", "heavy"] {
            assert!(all.contains(spec), "intensity {spec} missing from tables");
        }
        for p in SchedPolicyKind::ALL {
            assert!(all.contains(&p.label()), "policy {} missing from tables", p.label());
        }
        assert!(all.contains("vs fault-free"));
        assert!(all.contains("slow-path"));
        assert!(all.contains("best dynamic"));
    }

    #[test]
    fn single_intensity_restriction_runs() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, Some(FaultConfig::nack(0.1))).unwrap();
        let all: String = tables.iter().map(|t| t.render()).collect();
        assert!(all.contains("nack:10"));
        assert!(!all.contains("heavy"), "restricted axis must not sweep other intensities");
    }
}
