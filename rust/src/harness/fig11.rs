//! Fig. 11: the CoroAMU compiler's prefetch-based codegen vs hand-written
//! coroutines on the Xeon preset, sweeping the number of coroutines.
//! Paper: hand coroutines peak at 8-32 and average 1.40x/2.01x
//! (local/NUMA); the compiler reaches 2.11x/2.78x with a wider optimal
//! window (headline: 1.51x over SOTA coroutines).

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

pub const COUNTS: [usize; 6] = [2, 4, 8, 16, 32, 64];

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    // One engine session for both placements: each (variant, n) kernel
    // compiles once and is reused across benches' latency points.
    let mut matrix = Vec::new();
    for (loc, lat) in [("local", 90.0), ("numa", 130.0)] {
        for b in opts.bench_names() {
            matrix.push(
                RunRequest::new(b.clone(), Variant::Serial)
                    .tasks(1)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key(loc)
                    .latency_ns(lat),
            );
            for n in COUNTS {
                for v in [Variant::Coroutine, Variant::CoroAmuS] {
                    matrix.push(
                        RunRequest::new(b.clone(), v)
                            .tasks(n)
                            .scale(opts.scale)
                            .seed(opts.seed)
                            .key(format!("{loc}/{n}"))
                            .latency_ns(lat),
                    );
                }
            }
        }
    }
    let rs = grid::fetch(SimConfig::skylake(), &matrix, opts.threads)?;
    let mut tables = Vec::new();
    for loc in ["local", "numa"] {
        let mut t = Table::new(
            format!("Fig 11 ({loc}): speedup vs serial, hand Coroutine -> CoroAMU-S compiler"),
            &["bench", "variant", "n=2", "n=4", "n=8", "n=16", "n=32", "n=64", "best"],
        );
        let mut best_hand = Vec::new();
        let mut best_comp = Vec::new();
        for b in opts.bench_names() {
            let serial = lookup(&rs, &b, Variant::Serial, loc).unwrap().stats.cycles as f64;
            for (v, bests) in [(Variant::Coroutine, &mut best_hand), (Variant::CoroAmuS, &mut best_comp)] {
                let series: Vec<f64> = COUNTS
                    .iter()
                    .map(|n| {
                        let c = lookup(&rs, &b, v, &format!("{loc}/{n}")).unwrap().stats.cycles;
                        serial / c as f64
                    })
                    .collect();
                let best = series.iter().cloned().fold(0.0f64, f64::max);
                bests.push(best);
                let mut row = vec![b.clone(), v.label().into()];
                row.extend(series.iter().map(|s| speedup(*s)));
                row.push(speedup(best));
                t.row(row);
            }
        }
        let ratio = geomean(&best_comp) / geomean(&best_hand).max(1e-9);
        t.row(vec![
            "geomean(best)".into(),
            format!("compiler/hand = {:.2}x (paper 1.51x)", ratio),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            format!("{} vs {}", speedup(geomean(&best_comp)), speedup(geomean(&best_hand))),
        ]);
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn fig11_tiny_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert_eq!(ts.len(), 2);
        assert!(ts[0].render().contains("CoroAMU-S"));
    }
}
