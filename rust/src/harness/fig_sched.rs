//! Scheduler-policy comparison table (`coroamu report --sched`): the
//! `sim::sched` axis — {fifo, arrival, batched, latency} × far-memory
//! latency {200, 800} ns × {gups, bfs, hj} — swept through one engine
//! session. This is the scenario-diversity companion to Fig. 12: instead
//! of sweeping the *variant* it sweeps *which coroutine resumes next*,
//! plus the memory-guided prediction coverage each policy keeps (§IV-A).

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::sim::sched::SchedPolicyKind;
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

pub const LATENCIES_NS: [f64; 2] = [200.0, 800.0];

/// The irregular subset the policy axis discriminates on: random scatter
/// (gups), pointer chasing (bfs) and dependent hashing (hj).
pub const DEFAULT_BENCHES: [&str; 3] = ["gups", "bfs", "hj"];

fn benches(opts: &FigOpts) -> Vec<String> {
    if opts.only.is_empty() {
        DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect()
    } else {
        opts.only.clone()
    }
}

/// The request matrix: per (latency, bench) a serial baseline plus one
/// CoroAMU-Full run per policy; per policy one CoroAMU-D (getfin) run at
/// the low latency for the prediction-coverage table. Policy and latency
/// are simulate-time knobs, so the whole matrix compiles each kernel
/// exactly once per variant.
pub fn requests(opts: &FigOpts) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for lat in LATENCIES_NS {
        for b in benches(opts) {
            matrix.push(
                RunRequest::new(b.clone(), Variant::Serial)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .latency_ns(lat)
                    .key(format!("{lat}")),
            );
            for p in SchedPolicyKind::ALL {
                matrix.push(
                    RunRequest::new(b.clone(), Variant::CoroAmuFull)
                        .scale(opts.scale)
                        .seed(opts.seed)
                        .latency_ns(lat)
                        .policy(p)
                        .key(format!("{lat}/{}", p.label())),
                );
            }
        }
    }
    // Prediction-coverage rows: the getfin scheduler's indirect jump
    // under each policy, on the first benchmark at the low latency.
    if let Some(b) = benches(opts).first() {
        for p in SchedPolicyKind::ALL {
            matrix.push(
                RunRequest::new(b.clone(), Variant::CoroAmuD)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .latency_ns(LATENCIES_NS[0])
                    .policy(p)
                    .key(format!("pred/{}", p.label())),
            );
        }
    }
    matrix
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let rs = grid::fetch(SimConfig::nh_g(), &requests(opts), opts.threads)?;
    let benches = benches(opts);
    let mut tables = Vec::new();

    for lat in LATENCIES_NS {
        let mut cols: Vec<String> = vec!["policy".into()];
        cols.extend(benches.iter().cloned());
        cols.push("geomean".into());
        let mut t = Table::new(
            format!("Scheduler-policy sweep: CoroAMU-Full speedup vs serial, far latency {lat} ns"),
            &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for p in SchedPolicyKind::ALL {
            let mut row = vec![p.label()];
            let mut sp = Vec::new();
            for b in &benches {
                let serial =
                    lookup(&rs, b, Variant::Serial, &format!("{lat}")).unwrap().stats.cycles as f64;
                let full = lookup(&rs, b, Variant::CoroAmuFull, &format!("{lat}/{}", p.label()))
                    .unwrap()
                    .stats
                    .cycles as f64;
                sp.push(serial / full);
                row.push(speedup(serial / full));
            }
            row.push(speedup(geomean(&sp)));
            t.row(row);
        }
        tables.push(t);
    }

    // Scheduler behavior at the low latency: how each policy spends its
    // polls, and what it costs the front end.
    let lat = LATENCIES_NS[0];
    let mut bt = Table::new(
        format!("Scheduler behavior (CoroAMU-Full, {lat} ns)"),
        &["policy", "bench", "switches", "picks", "holds", "bafin mispred"],
    );
    for p in SchedPolicyKind::ALL {
        for b in &benches {
            let key = format!("{lat}/{}", p.label());
            let st = &lookup(&rs, b, Variant::CoroAmuFull, &key).unwrap().stats;
            bt.row(vec![
                p.label(),
                b.clone(),
                st.switches.to_string(),
                st.sched_picks.to_string(),
                st.sched_holds.to_string(),
                st.bafin_mispredicts.to_string(),
            ]);
        }
    }
    tables.push(bt);

    // Memory-guided prediction coverage (§IV-A as a policy property):
    // getfin dispatches through ITTAGE (policy shapes the target stream),
    // bafin keeps its oracle only under memory-guided policies.
    if let Some(b) = benches.first() {
        let mut pt = Table::new(
            format!("Memory-guided prediction coverage ({b}, {lat} ns)"),
            &[
                "policy",
                "getfin sched jumps",
                "getfin sched mispred",
                "bafin taken",
                "bafin mispred",
            ],
        );
        for p in SchedPolicyKind::ALL {
            let dkey = format!("pred/{}", p.label());
            let fkey = format!("{lat}/{}", p.label());
            let d = &lookup(&rs, b, Variant::CoroAmuD, &dkey).unwrap().stats;
            let f = &lookup(&rs, b, Variant::CoroAmuFull, &fkey).unwrap().stats;
            pt.row(vec![
                p.label(),
                d.sched_indirect_jumps.to_string(),
                d.sched_indirect_mispredicts.to_string(),
                f.bafins_taken.to_string(),
                f.bafin_mispredicts.to_string(),
            ]);
        }
        tables.push(pt);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_the_acceptance_axis() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let m = requests(&opts);
        // 2 latencies x 3 benches x (serial + 4 policies) + 4 prediction rows.
        assert_eq!(m.len(), 2 * 3 * 5 + 4);
        for p in SchedPolicyKind::ALL {
            assert!(
                m.iter().filter(|r| r.sched_policy == Some(p)).count() >= 2 * 3,
                "{} missing from the matrix",
                p.label()
            );
        }
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts).unwrap();
        // 2 speedup tables + behavior + prediction coverage.
        assert_eq!(tables.len(), 4);
        let all: String = tables.iter().map(|t| t.render()).collect();
        for p in SchedPolicyKind::ALL {
            assert!(all.contains(&p.label()), "policy {} missing from tables", p.label());
        }
        assert!(all.contains("bafin mispred"));
    }
}
