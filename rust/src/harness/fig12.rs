//! Fig. 12: CoroAMU performance normalized to serial on NH-G as far-memory
//! latency sweeps 100-800 ns. The paper's headline numbers: average 3.39x
//! at 200 ns and 4.87x at 800 ns (up to 29x / 59.8x on GUPS).

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

pub const LATENCIES_NS: [f64; 4] = [100.0, 200.0, 400.0, 800.0];
/// Static-prefetch concurrency candidates (best is reported, as in the
/// paper's per-benchmark labels).
const S_TASKS: [usize; 3] = [16, 32, 64];
const DYN_TASKS: usize = 96;

/// The full request matrix: 4 latencies x benches x 7 configurations.
/// Latency is a link-time override, so the engine compiles each
/// (bench, variant, tasks) kernel once for the whole figure instead of
/// once per latency point.
pub fn requests(opts: &FigOpts) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for lat in LATENCIES_NS {
        for b in opts.bench_names() {
            let mk = |variant: Variant, tasks: usize, key: String| {
                RunRequest::new(b.clone(), variant)
                    .tasks(tasks)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key(key)
                    .latency_ns(lat)
            };
            matrix.push(mk(Variant::Serial, 1, format!("{lat}")));
            matrix.push(mk(Variant::Coroutine, 16, format!("{lat}")));
            for t in S_TASKS {
                matrix.push(mk(Variant::CoroAmuS, t, format!("{lat}/{t}")));
            }
            matrix.push(mk(Variant::CoroAmuD, DYN_TASKS, format!("{lat}")));
            matrix.push(mk(Variant::CoroAmuFull, DYN_TASKS, format!("{lat}")));
        }
    }
    matrix
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let rs = grid::fetch(SimConfig::nh_g(), &requests(opts), opts.threads)?;
    let benches = opts.bench_names();
    let mut tables = Vec::new();
    for lat in LATENCIES_NS {
        let key = format!("{lat}");
        let mut t = Table::new(
            format!("Fig 12: speedup vs serial, NH-G, far latency {lat} ns"),
            &["bench", "Coroutine", "CoroAMU-S(best n)", "CoroAMU-D", "CoroAMU-Full"],
        );
        let mut per_variant: [Vec<f64>; 4] = Default::default();
        for b in &benches {
            let serial = lookup(&rs, b, Variant::Serial, &key).unwrap().stats.cycles as f64;
            let coro = serial / lookup(&rs, b, Variant::Coroutine, &key).unwrap().stats.cycles as f64;
            let (s_best, s_n) = S_TASKS
                .iter()
                .map(|n| {
                    let c = lookup(&rs, b, Variant::CoroAmuS, &format!("{lat}/{n}")).unwrap().stats.cycles;
                    (serial / c as f64, *n)
                })
                .fold((0.0, 0), |acc, x| if x.0 > acc.0 { x } else { acc });
            let d = serial / lookup(&rs, b, Variant::CoroAmuD, &key).unwrap().stats.cycles as f64;
            let f = serial / lookup(&rs, b, Variant::CoroAmuFull, &key).unwrap().stats.cycles as f64;
            per_variant[0].push(coro);
            per_variant[1].push(s_best);
            per_variant[2].push(d);
            per_variant[3].push(f);
            t.row(vec![
                b.clone(),
                speedup(coro),
                format!("{} ({s_n})", speedup(s_best)),
                speedup(d),
                speedup(f),
            ]);
        }
        t.row(vec![
            "geomean".into(),
            speedup(geomean(&per_variant[0])),
            speedup(geomean(&per_variant[1])),
            speedup(geomean(&per_variant[2])),
            speedup(geomean(&per_variant[3])),
        ]);
        tables.push(t);
    }
    // Headline comparison.
    let mut hl = Table::new(
        "Fig 12 headline: CoroAMU-Full average speedup (paper: 3.39x @200ns, 4.87x @800ns)",
        &["latency", "measured", "paper"],
    );
    for (lat, paper) in [(200.0, "3.39x"), (800.0, "4.87x")] {
        let key = format!("{lat}");
        let mut sp = Vec::new();
        for b in &benches {
            let serial = lookup(&rs, b, Variant::Serial, &key).unwrap().stats.cycles as f64;
            let f = lookup(&rs, b, Variant::CoroAmuFull, &key).unwrap().stats.cycles as f64;
            sp.push(serial / f);
        }
        hl.row(vec![format!("{lat} ns"), speedup(geomean(&sp)), paper.into()]);
    }
    tables.push(hl);
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_all_cells() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let m = requests(&opts);
        // 4 latencies x 8 benches x (serial + hand + 3xS + D + Full).
        assert_eq!(m.len(), 4 * 8 * 7);
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts).unwrap();
        assert_eq!(tables.len(), LATENCIES_NS.len() + 1);
        let rendered = tables.last().unwrap().render();
        assert!(rendered.contains("3.39x"));
    }
}
