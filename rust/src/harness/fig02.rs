//! Fig. 2: serial vs (hand-written, prefetch-based) coroutine execution on
//! the Intel Xeon preset, with local (~90 ns) and cross-NUMA (~130 ns)
//! placements, against the zero-overhead perfect-cache bound.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::util::table::{speedup, Table};
use anyhow::Result;

const CORO_TASKS: usize = 8; // the paper's typical sweet spot on Xeon

// Placement → emulated far-memory latency on the Xeon preset. "local"
// collapses the far tier to DRAM distance; "perfect" models a perfect
// cache at L2-like distance.
const PLACEMENTS: [(&str, f64, Variant, usize); 5] = [
    ("serial-local", 90.0, Variant::Serial, 1),
    ("coro-local", 90.0, Variant::Coroutine, CORO_TASKS),
    ("serial-numa", 130.0, Variant::Serial, 1),
    ("coro-numa", 130.0, Variant::Coroutine, CORO_TASKS),
    ("perfect", 8.0, Variant::Serial, 1),
];

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let mut matrix = Vec::new();
    for b in opts.bench_names() {
        for (key, lat, variant, tasks) in PLACEMENTS {
            matrix.push(
                RunRequest::new(b.clone(), variant)
                    .tasks(tasks)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key(key)
                    .latency_ns(lat),
            );
        }
    }
    let rs = grid::fetch(SimConfig::skylake(), &matrix, opts.threads)?;
    let mut t = Table::new(
        format!("Fig 2: coroutine speedup over serial on Xeon preset ({CORO_TASKS} coroutines)"),
        &["bench", "coro/serial (local)", "coro/serial (numa)", "perfect-cache bound (numa)"],
    );
    for b in opts.bench_names() {
        let g = |key: &str, v: Variant| lookup(&rs, &b, v, key).unwrap().stats.cycles as f64;
        let sl = g("serial-local", Variant::Serial);
        let cl = g("coro-local", Variant::Coroutine);
        let sn = g("serial-numa", Variant::Serial);
        let cn = g("coro-numa", Variant::Coroutine);
        let pf = g("perfect", Variant::Serial);
        t.row(vec![b.clone(), speedup(sl / cl), speedup(sn / cn), speedup(sn / pf)]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn fig2_tiny_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["bs".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("bs"));
    }
}
