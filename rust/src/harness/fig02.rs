//! Fig. 2: serial vs (hand-written, prefetch-based) coroutine execution on
//! the Intel Xeon preset, with local (~90 ns) and cross-NUMA (~130 ns)
//! placements, against the zero-overhead perfect-cache bound.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use crate::coordinator::{lookup, run_matrix, Job};
use crate::util::table::{speedup, Table};
use anyhow::Result;

const CORO_TASKS: usize = 8; // the paper's typical sweet spot on Xeon

fn cfg_local() -> SimConfig {
    // "local": far tier collapses to local DRAM distance.
    SimConfig::skylake().with_far_latency_ns(90.0)
}

fn cfg_numa() -> SimConfig {
    SimConfig::skylake().with_far_latency_ns(130.0)
}

fn cfg_perfect() -> SimConfig {
    // Perfect cache: remote data at L2-like distance.
    SimConfig::skylake().with_far_latency_ns(8.0)
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let mut jobs = Vec::new();
    for b in opts.bench_names() {
        for (key, cfg, variant, tasks) in [
            ("serial-local", cfg_local(), Variant::Serial, 1),
            ("coro-local", cfg_local(), Variant::Coroutine, CORO_TASKS),
            ("serial-numa", cfg_numa(), Variant::Serial, 1),
            ("coro-numa", cfg_numa(), Variant::Coroutine, CORO_TASKS),
            ("perfect", cfg_perfect(), Variant::Serial, 1),
        ] {
            jobs.push(Job {
                bench: b.clone(),
                variant,
                tasks,
                cfg,
                scale: opts.scale,
                seed: opts.seed,
                key: key.into(),
            });
        }
    }
    let rs = run_matrix(jobs, opts.threads)?;
    let mut t = Table::new(
        format!("Fig 2: coroutine speedup over serial on Xeon preset ({CORO_TASKS} coroutines)"),
        &["bench", "coro/serial (local)", "coro/serial (numa)", "perfect-cache bound (numa)"],
    );
    for b in opts.bench_names() {
        let g = |key: &str, v: Variant| lookup(&rs, &b, v, key).unwrap().stats.cycles as f64;
        let sl = g("serial-local", Variant::Serial);
        let cl = g("coro-local", Variant::Coroutine);
        let sn = g("serial-numa", Variant::Serial);
        let cn = g("coro-numa", Variant::Coroutine);
        let pf = g("perfect", Variant::Serial);
        t.row(vec![b.clone(), speedup(sl / cl), speedup(sn / cn), speedup(sn / pf)]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn fig2_tiny_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["bs".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("bs"));
    }
}
