//! SLO-aware service tables (`coroamu report --service`): the
//! `sim::service` axis — an open-loop offered-load sweep over the
//! calibrated batch runs at the high-latency disaggregation point.
//! Where `fig_faults` sweeps how the fabric *fails*, this sweeps how a
//! request-serving deployment *saturates*: each batch run calibrates the
//! per-request cost (the knee) under its (latency, policy, fabric,
//! faults) composition, then the deterministic queueing replay maps out
//! the throughput–latency curve, the saturation knee and the
//! goodput-vs-throughput gap that admission control and load shedding
//! open up past it.
//!
//! Service, policy, fabric and faults are all simulate-time knobs, so
//! the whole matrix compiles each (benchmark, variant) kernel exactly
//! once and builds each dataset exactly once.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::sim::fabric::FabricKind;
use crate::sim::faults::FaultConfig;
use crate::sim::sched::SchedPolicyKind;
use crate::sim::service::ServiceConfig;
use crate::util::table::Table;
use anyhow::Result;

/// The far-latency point the overload axis is measured at: the paper's
/// high-disaggregation setting, where the per-request cost (and so the
/// saturation knee) is dominated by far-memory stalls.
pub const LATENCY_NS: f64 = 800.0;

/// The irregular subset the overload axis discriminates on (same set as
/// the fabric and chaos sweeps): random scatter (gups), pointer chasing
/// (bfs) and dependent hashing (hj).
pub const DEFAULT_BENCHES: [&str; 3] = ["gups", "bfs", "hj"];

/// The resume policies joined into the overload composition table: the
/// static baseline and the latency-aware reranker.
pub const POLICIES: [SchedPolicyKind; 2] =
    [SchedPolicyKind::ArrivalOrder, SchedPolicyKind::LatencyAware];

fn benches(opts: &FigOpts) -> Vec<String> {
    if opts.only.is_empty() {
        DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect()
    } else {
        opts.only.clone()
    }
}

/// The swept offered loads (percent of measured capacity), or a single
/// spec when the CLI restricts the axis (`report --service overload`).
/// The sweep brackets the knee: comfortably under, at, and 2× past it.
pub fn loads(only: Option<ServiceConfig>) -> Vec<ServiceConfig> {
    match only {
        Some(s) => vec![s],
        None => [50, 75, 90, 100, 125, 150, 200]
            .iter()
            .map(|&pct| ServiceConfig { load_pct: pct, ..ServiceConfig::steady() })
            .collect(),
    }
}

/// The (fabric × faults) compositions the overload point is replayed
/// under: each one changes the calibrated per-request cost, which moves
/// the knee — the latency-aware coupling the tentpole is about.
pub fn compositions() -> Vec<(FabricKind, FaultConfig)> {
    vec![
        (FabricKind::FixedDelay, FaultConfig::off()),
        (FabricKind::Queued { depth: 16 }, FaultConfig::off()),
        (FabricKind::FixedDelay, FaultConfig::heavy()),
        (FabricKind::Queued { depth: 16 }, FaultConfig::heavy()),
    ]
}

/// The overload point for the composition table: the single restricted
/// spec when the axis is restricted, else 2× the knee.
fn overload_spec(specs: &[ServiceConfig]) -> ServiceConfig {
    if specs.len() == 1 {
        specs[0]
    } else {
        ServiceConfig::overload()
    }
}

/// Key of a clean-baseline curve point.
fn curve_key(s: &ServiceConfig) -> String {
    format!("curve/{}", s.label())
}

/// Key of a composition run for (service, fabric, faults, policy).
fn comp_key(s: &ServiceConfig, f: FabricKind, fl: &FaultConfig, p: SchedPolicyKind) -> String {
    format!("{}/{}/{}/{}", s.label(), f.label(), fl.label(), p.label())
}

/// The request matrix: per bench the offered-load curve on the clean
/// composition (fixed fabric, no faults, arrival order), then the
/// overload point under every (fabric × faults × policy) composition.
pub fn requests(opts: &FigOpts, specs: &[ServiceConfig]) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for b in benches(opts) {
        for svc in specs {
            matrix.push(
                RunRequest::new(b.clone(), Variant::CoroAmuFull)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .latency_ns(LATENCY_NS)
                    .service(*svc)
                    .key(curve_key(svc)),
            );
        }
        let over = overload_spec(specs);
        for (fabric, faults) in compositions() {
            for p in POLICIES {
                matrix.push(
                    RunRequest::new(b.clone(), Variant::CoroAmuFull)
                        .scale(opts.scale)
                        .seed(opts.seed)
                        .latency_ns(LATENCY_NS)
                        .service(over)
                        .fabric(fabric)
                        .faults(faults)
                        .policy(p)
                        .key(comp_key(&over, fabric, &faults, p)),
                );
            }
        }
    }
    matrix
}

pub fn run(opts: &FigOpts, only: Option<ServiceConfig>) -> Result<Vec<Table>> {
    let specs = loads(only);
    let rs = grid::fetch(SimConfig::nh_g(), &requests(opts, &specs), opts.threads)?;
    let benches = benches(opts);
    let mut tables = Vec::new();

    // T1: the throughput–latency curve — offered load vs goodput,
    // throughput and sojourn tail per bench, on the clean composition.
    let mut t1 = Table::new(
        format!("Throughput–latency curve: open-loop load sweep ({LATENCY_NS} ns)"),
        &[
            "bench", "load", "cost", "offered", "served", "goodput", "rejected", "shed",
            "timed out", "p50", "p99", "p99.9",
        ],
    );
    for b in &benches {
        for svc in &specs {
            let st = &lookup(&rs, b, Variant::CoroAmuFull, &curve_key(svc)).unwrap().stats;
            t1.row(vec![
                b.clone(),
                svc.label(),
                st.svc_capacity_cost.to_string(),
                st.svc_offered.to_string(),
                st.svc_served.to_string(),
                st.svc_goodput.to_string(),
                st.svc_rejected.to_string(),
                st.svc_shed_expired.to_string(),
                st.svc_timed_out.to_string(),
                st.svc_p50.to_string(),
                st.svc_p99.to_string(),
                st.svc_p999.to_string(),
            ]);
        }
    }
    tables.push(t1);

    // T2: saturation knee per bench — the highest swept load whose
    // goodput still covers >= 90% of the offered requests, and how much
    // goodput survives at the top of the sweep (graceful degradation).
    let mut t2 = Table::new(
        "Saturation knee and goodput retention",
        &["bench", "knee load", "cost", "goodput @ knee", "peak goodput", "goodput @ max load", "retention"],
    );
    for b in &benches {
        let pt = |svc: &ServiceConfig| {
            lookup(&rs, b, Variant::CoroAmuFull, &curve_key(svc)).unwrap().stats.clone()
        };
        // The knee: the highest swept load whose goodput still covers
        // >= 90% of the offered requests (lowest point as a fallback).
        let mut knee = &specs[0];
        for s in &specs {
            let st = pt(s);
            if st.svc_goodput * 10 >= st.svc_offered * 9 && s.load_pct >= knee.load_pct {
                knee = s;
            }
        }
        let peak = specs.iter().map(|s| pt(s).svc_goodput).max().unwrap_or(0);
        let top = specs.iter().max_by_key(|s| s.load_pct).unwrap_or(&specs[0]);
        let knee_st = pt(knee);
        let top_st = pt(top);
        t2.row(vec![
            b.clone(),
            knee.label(),
            knee_st.svc_capacity_cost.to_string(),
            knee_st.svc_goodput.to_string(),
            peak.to_string(),
            top_st.svc_goodput.to_string(),
            if peak > 0 {
                format!("{:.0}%", 100.0 * top_st.svc_goodput as f64 / peak as f64)
            } else {
                "-".into()
            },
        ]);
    }
    tables.push(t2);

    // T3: the overload point under every (policy × fabric × faults)
    // composition — heavier compositions inflate the calibrated cost
    // (the knee moves), while shedding keeps the goodput share bounded.
    let over = overload_spec(&specs);
    let mut t3 = Table::new(
        format!("Overload composition ({}, policy × fabric × faults)", over.label()),
        &[
            "bench", "policy", "fabric", "faults", "cost", "goodput", "rejected", "shed",
            "p99", "degraded",
        ],
    );
    for b in &benches {
        for (fabric, faults) in compositions() {
            for p in POLICIES {
                let st = &lookup(&rs, b, Variant::CoroAmuFull, &comp_key(&over, fabric, &faults, p))
                    .unwrap()
                    .stats;
                t3.row(vec![
                    b.clone(),
                    p.label(),
                    fabric.label(),
                    faults.label(),
                    st.svc_capacity_cost.to_string(),
                    st.svc_goodput.to_string(),
                    st.svc_rejected.to_string(),
                    st.svc_shed_expired.to_string(),
                    st.svc_p99.to_string(),
                    format!("{} in {} spells", st.svc_degraded_served, st.svc_degraded_spells),
                ]);
            }
        }
    }
    tables.push(t3);

    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_the_acceptance_axis() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let specs = loads(None);
        assert_eq!(specs.len(), 7);
        let m = requests(&opts, &specs);
        // 3 benches x (7 curve points + 4 compositions x 2 policies).
        assert_eq!(m.len(), 3 * (7 + 4 * 2));
        // Every curve point names its load; the composition runs cover
        // heavy faults and the queued fabric at the overload point.
        for svc in &specs {
            assert!(
                m.iter().filter(|r| r.service == Some(*svc)).count() >= 3,
                "{} missing from the matrix",
                svc.label()
            );
        }
        assert_eq!(
            m.iter().filter(|r| r.faults == Some(FaultConfig::heavy())).count(),
            3 * 2 * 2,
            "heavy-faults composition missing"
        );
        assert!(m
            .iter()
            .filter(|r| r.faults == Some(FaultConfig::heavy()))
            .all(|r| r.service == Some(ServiceConfig::overload())));
        // Restricting the axis keeps one load for both the curve and
        // the composition runs.
        let one = requests(&opts, &loads(Some(ServiceConfig::knee())));
        assert_eq!(one.len(), 3 * (1 + 4 * 2));
        assert!(one.iter().all(|r| r.service == Some(ServiceConfig::knee())));
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, None).unwrap();
        // curve + knee + composition.
        assert_eq!(tables.len(), 3);
        let all: String = tables.iter().map(|t| t.render()).collect();
        for spec in ["load:50", "knee", "overload"] {
            assert!(all.contains(spec), "load {spec} missing from tables");
        }
        assert!(all.contains("goodput"), "{all}");
        assert!(all.contains("p99"), "{all}");
        assert!(all.contains("heavy"), "heavy-faults composition missing: {all}");
        assert!(all.contains("queued"), "queued-fabric composition missing: {all}");
        assert!(all.contains("latency"), "latency-aware policy missing: {all}");
    }

    #[test]
    fn single_load_restriction_runs() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, Some(ServiceConfig::parse("load:120").unwrap())).unwrap();
        let all: String = tables.iter().map(|t| t.render()).collect();
        assert!(all.contains("load:120"), "{all}");
        assert!(!all.contains("load:50"), "restricted axis must not sweep other loads: {all}");
    }
}
