//! Fig. 3: runtime breakdown of coroutine-optimized applications on the
//! Xeon preset (cross-NUMA). The paper's finding: scheduler + context
//! switching each exceed ~30% of execution on average — the motivation for
//! memory-centric codegen.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::RunRequest;
use crate::util::table::{pct, Table};
use anyhow::Result;

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let matrix: Vec<RunRequest> = opts
        .bench_names()
        .into_iter()
        .map(|b| {
            RunRequest::new(b, Variant::Coroutine)
                .tasks(8)
                .scale(opts.scale)
                .seed(opts.seed)
                .key("numa")
        })
        .collect();
    let rs = grid::fetch(SimConfig::skylake().with_far_latency_ns(130.0), &matrix, opts.threads)?;
    let mut t = Table::new(
        "Fig 3: cycle breakdown of hand-coroutine apps (Xeon, cross-NUMA)",
        &["bench", "compute", "local/ctx", "remote", "scheduler", "mispredict"],
    );
    let mut sums = [0.0f64; 5];
    for r in &rs {
        let b = r.stats.cycle_breakdown();
        for (i, (_, v)) in b.iter().enumerate() {
            sums[i] += v;
        }
        t.row(vec![
            r.bench.clone(),
            pct(b[0].1),
            pct(b[1].1),
            pct(b[2].1),
            pct(b[3].1),
            pct(b[4].1),
        ]);
    }
    let n = rs.len().max(1) as f64;
    t.row(vec![
        "average".into(),
        pct(sums[0] / n),
        pct(sums[1] / n),
        pct(sums[2] / n),
        pct(sums[3] / n),
        pct(sums[4] / n),
    ]);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn fig3_breakdown_rows_sum_near_one() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("average"));
    }
}
