//! Far-fabric comparison tables (`coroamu report --fabric`): the
//! `sim::fabric` axis — {fixed, queued, dist, tiered} × variants ×
//! scheduler policies at the high-latency disaggregation point. This is
//! the scenario-diversity companion to the two-point latency sweep of
//! Fig. 12: instead of sweeping *how far* the far pool is, it sweeps
//! *how the fabric behaves* (congestion, variance, tiering), and shows
//! where dynamic coroutine scheduling (`sim::sched`) beats a static
//! resume order once completion times stop being deterministic.
//!
//! Fabric, policy and latency are all simulate-time knobs, so the whole
//! matrix compiles each (benchmark, variant) kernel exactly once and
//! builds each dataset exactly once.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::sim::fabric::FabricKind;
use crate::sim::sched::SchedPolicyKind;
use crate::util::table::{geomean, speedup, Table};
use anyhow::Result;

/// The far-latency point the fabric axis is measured at: the paper's
/// high-disaggregation setting, where fabric behavior dominates.
pub const LATENCY_NS: f64 = 800.0;

/// The irregular subset the fabric axis discriminates on: random scatter
/// (gups), pointer chasing (bfs) and dependent hashing (hj) — bfs/hj
/// carry the access locality that makes the tiered backend diverge from
/// streaming behavior.
pub const DEFAULT_BENCHES: [&str; 3] = ["gups", "bfs", "hj"];

fn benches(opts: &FigOpts) -> Vec<String> {
    if opts.only.is_empty() {
        DEFAULT_BENCHES.iter().map(|s| s.to_string()).collect()
    } else {
        opts.only.clone()
    }
}

/// The swept fabric set: all four backends, or a single one when the
/// CLI restricts the axis (`report --fabric queued:8`).
pub fn fabrics(only: Option<FabricKind>) -> Vec<FabricKind> {
    match only {
        Some(f) => vec![f],
        None => FabricKind::ALL.to_vec(),
    }
}

/// The request matrix: per (fabric, bench) a serial baseline, a
/// CoroAMU-D run (variant table), and one CoroAMU-Full run per scheduler
/// policy (fabric × policy tables).
pub fn requests(opts: &FigOpts, fabrics: &[FabricKind]) -> Vec<RunRequest> {
    let mut matrix = Vec::new();
    for &f in fabrics {
        for b in benches(opts) {
            matrix.push(
                RunRequest::new(b.clone(), Variant::Serial)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .latency_ns(LATENCY_NS)
                    .fabric(f)
                    .key(f.label()),
            );
            matrix.push(
                RunRequest::new(b.clone(), Variant::CoroAmuD)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .latency_ns(LATENCY_NS)
                    .fabric(f)
                    .key(f.label()),
            );
            for p in SchedPolicyKind::ALL {
                matrix.push(
                    RunRequest::new(b.clone(), Variant::CoroAmuFull)
                        .scale(opts.scale)
                        .seed(opts.seed)
                        .latency_ns(LATENCY_NS)
                        .fabric(f)
                        .policy(p)
                        .key(format!("{}/{}", f.label(), p.label())),
                );
            }
        }
    }
    matrix
}

/// Key of the CoroAMU-Full run for (fabric, policy).
fn full_key(f: FabricKind, p: SchedPolicyKind) -> String {
    format!("{}/{}", f.label(), p.label())
}

pub fn run(opts: &FigOpts, only: Option<FabricKind>) -> Result<Vec<Table>> {
    let fabs = fabrics(only);
    let rs = grid::fetch(SimConfig::nh_g(), &requests(opts, &fabs), opts.threads)?;
    let benches = benches(opts);
    let arrival = SchedPolicyKind::ArrivalOrder;
    let mut tables = Vec::new();

    // T1: fabric × variant — what each fabric does to the decoupling
    // win itself (arrival order, the paper's native policy).
    let mut cols: Vec<String> = vec!["fabric".into()];
    for b in &benches {
        cols.push(format!("{b} D"));
        cols.push(format!("{b} Full"));
    }
    let mut t1 = Table::new(
        format!("Far-fabric sweep: speedup vs serial per variant ({LATENCY_NS} ns, arrival order)"),
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &f in &fabs {
        let mut row = vec![f.label()];
        for b in &benches {
            let serial = lookup(&rs, b, Variant::Serial, &f.label()).unwrap().stats.cycles as f64;
            let d = lookup(&rs, b, Variant::CoroAmuD, &f.label()).unwrap().stats.cycles as f64;
            let full =
                lookup(&rs, b, Variant::CoroAmuFull, &full_key(f, arrival)).unwrap().stats.cycles
                    as f64;
            row.push(speedup(serial / d));
            row.push(speedup(serial / full));
        }
        t1.row(row);
    }
    tables.push(t1);

    // T2: fabric × scheduler policy — where resume order starts to
    // matter once the fabric adds queuing, variance or tiering.
    let mut cols: Vec<String> = vec!["fabric".into(), "policy".into()];
    cols.extend(benches.iter().cloned());
    cols.push("geomean".into());
    let mut t2 = Table::new(
        format!("Fabric × policy: CoroAMU-Full speedup vs serial ({LATENCY_NS} ns)"),
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &f in &fabs {
        for p in SchedPolicyKind::ALL {
            let mut row = vec![f.label(), p.label()];
            let mut sp = Vec::new();
            for b in &benches {
                let serial =
                    lookup(&rs, b, Variant::Serial, &f.label()).unwrap().stats.cycles as f64;
                let full = lookup(&rs, b, Variant::CoroAmuFull, &full_key(f, p))
                    .unwrap()
                    .stats
                    .cycles as f64;
                sp.push(serial / full);
                row.push(speedup(serial / full));
            }
            row.push(speedup(geomean(&sp)));
            t2.row(row);
        }
    }
    tables.push(t2);

    // T3: what each fabric actually did to the requests (first bench,
    // CoroAMU-Full under arrival order).
    if let Some(b) = benches.first() {
        let mut t3 = Table::new(
            format!("Fabric behavior ({b}, CoroAMU-Full/arrival, {LATENCY_NS} ns)"),
            &[
                "fabric",
                "requests",
                "p50 lat",
                "p99 lat",
                "peak queue",
                "queue stalls",
                "hot-page hit",
                "writebacks",
            ],
        );
        for &f in &fabs {
            let st = &lookup(&rs, b, Variant::CoroAmuFull, &full_key(f, arrival)).unwrap().stats;
            let hot = st.fabric_hot_hits + st.fabric_hot_misses;
            t3.row(vec![
                f.label(),
                st.fabric_requests.to_string(),
                st.fabric_p50.to_string(),
                st.fabric_p99.to_string(),
                st.fabric_max_inflight.to_string(),
                st.fabric_queue_stalls.to_string(),
                if hot == 0 {
                    "-".into()
                } else {
                    format!("{:.0}%", 100.0 * st.fabric_hot_hits as f64 / hot as f64)
                },
                st.fabric_writebacks.to_string(),
            ]);
        }
        tables.push(t3);
    }

    // T4: dynamic vs static resume order — per (fabric, bench), cycles
    // under arrival order (the paper's static-completion-order baseline)
    // against the dynamic policies, with the winner's margin. Under the
    // fixed delayer the completion order is deterministic and arrival
    // order is essentially optimal; under variance the dynamic policies
    // find cells where it is not.
    let mut t4 = Table::new(
        format!("Dynamic vs static resume order under fabric variance ({LATENCY_NS} ns)"),
        &["fabric", "bench", "arrival", "latency-aware", "batched", "best dynamic", "gain"],
    );
    for &f in &fabs {
        for b in &benches {
            let cyc = |p: SchedPolicyKind| {
                lookup(&rs, b, Variant::CoroAmuFull, &full_key(f, p)).unwrap().stats.cycles
            };
            let base = cyc(arrival);
            let la = cyc(SchedPolicyKind::LatencyAware);
            let bw = cyc(SchedPolicyKind::BatchedWakeup(crate::sim::sched::DEFAULT_BATCH));
            let (best_label, best) =
                if la <= bw { ("latency", la) } else { ("batched", bw) };
            let gain = 100.0 * (base as f64 - best as f64) / base as f64;
            t4.row(vec![
                f.label(),
                b.clone(),
                base.to_string(),
                la.to_string(),
                bw.to_string(),
                best_label.into(),
                format!("{gain:+.2}%"),
            ]);
        }
    }
    tables.push(t4);

    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn request_matrix_covers_the_acceptance_axis() {
        let opts = FigOpts { scale: Scale::Tiny, ..FigOpts::quick() };
        let fabs = fabrics(None);
        let m = requests(&opts, &fabs);
        // 4 fabrics x 3 benches x (serial + D + 4 policies).
        assert_eq!(m.len(), 4 * 3 * 6);
        for f in FabricKind::ALL {
            assert!(
                m.iter().filter(|r| r.fabric == Some(f)).count() >= 3 * 6,
                "{} missing from the matrix",
                f.label()
            );
        }
        // Restricting the axis keeps one fabric only.
        let one = requests(&opts, &fabrics(Some(FabricKind::FixedDelay)));
        assert_eq!(one.len(), 3 * 6);
        assert!(one.iter().all(|r| r.fabric == Some(FabricKind::FixedDelay)));
    }

    #[test]
    fn runs_on_tiny_scale_single_bench() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, None).unwrap();
        // variant sweep + policy sweep + behavior + dynamic-vs-static.
        assert_eq!(tables.len(), 4);
        let all: String = tables.iter().map(|t| t.render()).collect();
        for f in FabricKind::ALL {
            assert!(all.contains(&f.label()), "fabric {} missing from tables", f.label());
        }
        for p in SchedPolicyKind::ALL {
            assert!(all.contains(&p.label()), "policy {} missing from tables", p.label());
        }
        assert!(all.contains("hot-page hit"));
        assert!(all.contains("best dynamic"));
    }

    #[test]
    fn single_fabric_restriction_runs() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["gups".into()], ..FigOpts::quick() };
        let tables = run(&opts, Some(FabricKind::Tiered { pages: 8 })).unwrap();
        let all: String = tables.iter().map(|t| t.render()).collect();
        assert!(all.contains("tiered:8"));
        assert!(!all.contains("queued:"), "restricted axis must not sweep other fabrics");
    }

    /// The acceptance scenario: once the fabric adds queuing or latency
    /// variance, at least one (fabric, bench) cell has a dynamic policy
    /// (latency-aware or batched wakeup) strictly beating arrival order —
    /// the resume order only matters when completion times stop being
    /// deterministic. Deterministic seeds make this a regression pin, not
    /// a flaky perf assertion.
    #[test]
    fn dynamic_scheduling_beats_arrival_order_under_variance() {
        use crate::sim::sched::DEFAULT_BATCH;
        let opts = FigOpts {
            scale: Scale::Tiny,
            only: vec!["gups".into(), "bfs".into()],
            ..FigOpts::quick()
        };
        let fabs = [
            FabricKind::Queued { depth: 8 },
            FabricKind::Distributed { dist: crate::sim::fabric::Dist::Bimodal },
            FabricKind::Tiered { pages: 8 },
        ];
        let m = requests(&opts, &fabs);
        let rs = crate::engine::Engine::new(SimConfig::nh_g()).sweep(&m, opts.threads).unwrap();
        let mut wins = Vec::new();
        let mut cells = Vec::new();
        for &f in &fabs {
            for b in ["gups", "bfs"] {
                let cyc = |p: SchedPolicyKind| {
                    lookup(&rs, b, Variant::CoroAmuFull, &full_key(f, p)).unwrap().stats.cycles
                };
                let base = cyc(SchedPolicyKind::ArrivalOrder);
                for (name, c) in [
                    ("latency", cyc(SchedPolicyKind::LatencyAware)),
                    ("batched", cyc(SchedPolicyKind::BatchedWakeup(DEFAULT_BATCH))),
                ] {
                    cells.push(format!("{}/{b}/{name}: {c} vs arrival {base}", f.label()));
                    if c < base {
                        wins.push((f.label(), b, name, base - c));
                    }
                }
            }
        }
        assert!(
            !wins.is_empty(),
            "no dynamic policy beat arrival order in any variance cell:\n{}",
            cells.join("\n")
        );
    }
}
