//! Figure/table harness: regenerates every data artifact of the paper's
//! evaluation (§V-§VI). One module per figure; each returns rendered
//! [`Table`]s so the CLI, the benches and EXPERIMENTS.md all share one
//! source of truth.
//!
//! | Artifact | Module | Content |
//! |----------|--------|---------|
//! | Table I  | `config::SimConfig::table1` | NH-G core configuration |
//! | Table II | `benchmarks::table2`        | benchmark inventory |
//! | Fig 2    | [`fig02`] | serial vs hand coroutines, local/NUMA, Xeon |
//! | Fig 3    | [`fig03`] | cycle breakdown of coroutine apps, Xeon |
//! | Fig 11   | [`fig11`] | compiler vs hand coroutines, #coroutine sweep |
//! | Fig 12   | [`fig12`] | CoroAMU speedups vs far-memory latency, NH-G |
//! | Fig 13   | [`fig13`] | dynamic instruction expansion |
//! | Fig 14   | [`fig14`] | cycle breakdown serial / getfin / bafin |
//! | Fig 15   | [`fig15`] | context + aggregation ablation |
//! | Fig 16   | [`fig16`] | memory-level parallelism |
//! | sched    | [`fig_sched`] | scheduler-policy sweep (`report --sched`) |
//! | fabric   | [`fig_fabric`] | far-fabric sweep (`report --fabric`) |
//! | cluster  | [`fig_cluster`] | cluster scaling sweep (`report --cluster`) |
//! | faults   | [`fig_faults`] | fault-injection chaos sweep (`report --faults`) |
//! | service  | [`fig_service`] | open-loop overload sweep (`report --service`) |

pub mod fig02;
pub mod fig03;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fig_cluster;
pub mod fig_fabric;
pub mod fig_faults;
pub mod fig_sched;
pub mod fig_service;
pub mod grid;

use crate::benchmarks::Scale;
use crate::coordinator::pool;
use crate::util::table::Table;
use anyhow::Result;

/// Options shared by all figure generators.
#[derive(Debug, Clone)]
pub struct FigOpts {
    pub scale: Scale,
    pub threads: usize,
    pub seed: u64,
    /// Restrict to these benchmarks (empty = all eight).
    pub only: Vec<String>,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts { scale: Scale::Full, threads: pool::default_threads(), seed: 42, only: vec![] }
    }
}

impl FigOpts {
    pub fn quick() -> Self {
        FigOpts { scale: Scale::Small, ..Default::default() }
    }

    pub fn bench_names(&self) -> Vec<String> {
        if self.only.is_empty() {
            crate::benchmarks::all().iter().map(|b| b.spec().name.to_string()).collect()
        } else {
            self.only.clone()
        }
    }
}

/// Generate one figure by number.
pub fn figure(n: u32, opts: &FigOpts) -> Result<Vec<Table>> {
    match n {
        2 => fig02::run(opts),
        3 => fig03::run(opts),
        11 => fig11::run(opts),
        12 => fig12::run(opts),
        13 => fig13::run(opts),
        14 => fig14::run(opts),
        15 => fig15::run(opts),
        16 => fig16::run(opts),
        other => anyhow::bail!("figure {other} is schematic (no data) or unknown; data figures: 2,3,11-16"),
    }
}

pub const ALL_FIGURES: [u32; 8] = [2, 3, 11, 12, 13, 14, 15, 16];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_figure_rejected() {
        assert!(figure(7, &FigOpts::quick()).is_err());
    }

    #[test]
    fn bench_name_filter() {
        let mut o = FigOpts::quick();
        assert_eq!(o.bench_names().len(), 8);
        o.only = vec!["gups".into()];
        assert_eq!(o.bench_names(), vec!["gups".to_string()]);
    }
}
