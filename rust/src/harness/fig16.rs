//! Fig. 16: memory-level parallelism (average in-flight requests at the
//! far-memory controller) for serial, prefetch-based CoroAMU-S, and
//! decoupled CoroAMU-Full. Paper: serial < 5, prefetch capped < 20 by
//! MSHRs, AMU reaches ~64 (scalable with coroutine count).

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::util::table::{mean, Table};
use anyhow::Result;

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    // CoroAMU-S at its typical best concurrency (16-32, Fig 11/12); more
    // tasks do not help prefetching past the MSHR/locality limits.
    let variants = [(Variant::Serial, 1usize), (Variant::CoroAmuS, 32), (Variant::CoroAmuFull, 96)];
    let mut matrix = Vec::new();
    for b in opts.bench_names() {
        for (v, tasks) in variants {
            matrix.push(
                RunRequest::new(b.clone(), v)
                    .tasks(tasks)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key("mlp"),
            );
        }
    }
    let rs = grid::fetch(SimConfig::nh_g().with_far_latency_ns(800.0), &matrix, opts.threads)?;
    let mut t = Table::new(
        "Fig 16: MLP at the far-memory controller @800ns (paper: serial <5, prefetch <20, AMU ~64)",
        &["bench", "Serial", "CoroAMU-S (prefetch)", "CoroAMU-Full (decoupled)"],
    );
    let mut cols: [Vec<f64>; 3] = Default::default();
    for b in opts.bench_names() {
        let mut row = vec![b.clone()];
        for (i, (v, _)) in variants.iter().enumerate() {
            let mlp = lookup(&rs, &b, *v, "mlp").unwrap().stats.far_mlp;
            cols[i].push(mlp);
            row.push(format!("{mlp:.1}"));
        }
        t.row(row);
    }
    t.row(vec![
        "mean".into(),
        format!("{:.1}", mean(&cols[0])),
        format!("{:.1}", mean(&cols[1])),
        format!("{:.1}", mean(&cols[2])),
    ]);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn decoupled_mlp_beats_serial_on_gups() {
        let opts = FigOpts { scale: Scale::Small, only: vec!["gups".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("mean"));
    }
}
