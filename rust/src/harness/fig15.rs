//! Fig. 15: compiler-optimization ablation at 100 ns: starting from
//! CoroAMU-D-with-bafin, add (2) context selection (§III-B) then (3)
//! request aggregation (§III-C). Reports normalized performance,
//! normalized switch count, and context operations per switch. Paper:
//! up to >20% performance gain; switch count drops with aggregation;
//! context ops per switch drop with selection.

use super::fig14::d_with_bafin;
use super::FigOpts;
use crate::benchmarks;
use crate::compiler::codegen::CodegenOpts;
use crate::config::SimConfig;
use crate::coordinator::pool;
use crate::util::table::Table;
use anyhow::Result;

pub fn configs() -> Vec<(&'static str, CodegenOpts)> {
    let base = d_with_bafin(96);
    let ctx = CodegenOpts { context_opt: true, ..base.clone() };
    let full = CodegenOpts { coalesce: true, ..ctx.clone() };
    vec![("(1) bafin-basic", base), ("(2) +context", ctx), ("(3) +aggregation", full)]
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let cfg = SimConfig::nh_g().with_far_latency_ns(100.0);
    let benches = opts.bench_names();
    let cfgs = configs();
    let cells: Vec<(String, usize)> =
        benches.iter().flat_map(|b| (0..cfgs.len()).map(move |i| (b.clone(), i))).collect();
    let stats = pool::parallel_map(cells.len(), opts.threads, |i| {
        let (b, ci) = &cells[i];
        let inst = benchmarks::by_name(b).unwrap().instance(opts.scale, opts.seed).unwrap();
        benchmarks::execute_opts(&cfg, inst, &cfgs[*ci].1)
            .unwrap_or_else(|e| panic!("fig15 {b}/{}: {e:#}", cfgs[*ci].0))
    });
    let mut t = Table::new(
        "Fig 15: ablation @100ns (normalized to bafin-basic)",
        &["bench", "config", "perf", "switches", "ctx ops/switch"],
    );
    for b in &benches {
        let idx = |ci: usize| cells.iter().position(|(bb, c)| bb == b && *c == ci).unwrap();
        let base = &stats[idx(0)];
        for (ci, (cname, _)) in cfgs.iter().enumerate() {
            let s = &stats[idx(ci)];
            t.row(vec![
                b.clone(),
                cname.to_string(),
                format!("{:.2}x", base.cycles as f64 / s.cycles as f64),
                format!("{:.2}", s.switches as f64 / base.switches.max(1) as f64),
                format!("{:.1}", s.ctx_ops_per_switch()),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn aggregation_reduces_switches_on_stream() {
        let opts = FigOpts { scale: Scale::Small, only: vec!["stream".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("+aggregation"));
    }
}
