//! Fig. 15: compiler-optimization ablation at 100 ns: starting from
//! CoroAMU-D-with-bafin, add (2) context selection (§III-B) then (3)
//! request aggregation (§III-C). Reports normalized performance,
//! normalized switch count, and context operations per switch. Paper:
//! up to >20% performance gain; switch count drops with aggregation;
//! context ops per switch drop with selection.

use super::fig14::d_with_bafin;
use super::FigOpts;
use crate::compiler::codegen::CodegenOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::RunRequest;
use crate::util::table::Table;
use anyhow::Result;

pub fn configs() -> Vec<(&'static str, CodegenOpts)> {
    let base = d_with_bafin(96);
    let ctx = CodegenOpts { context_opt: true, ..base.clone() };
    let full = CodegenOpts { coalesce: true, ..ctx.clone() };
    vec![("(1) bafin-basic", base), ("(2) +context", ctx), ("(3) +aggregation", full)]
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let benches = opts.bench_names();
    let cfgs = configs();
    // Bench-major, config-minor; consumed positionally below.
    let matrix: Vec<RunRequest> = benches
        .iter()
        .flat_map(|b| {
            cfgs.iter().map(move |(cname, co)| {
                RunRequest::new(b.clone(), Variant::CoroAmuFull)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key(cname.to_string())
                    .opts(co.clone(), cname.to_string())
            })
        })
        .collect();
    let rs = grid::fetch(SimConfig::nh_g().with_far_latency_ns(100.0), &matrix, opts.threads)?;
    let mut t = Table::new(
        "Fig 15: ablation @100ns (normalized to bafin-basic)",
        &["bench", "config", "perf", "switches", "ctx ops/switch"],
    );
    for (bi, b) in benches.iter().enumerate() {
        let base = &rs[bi * cfgs.len()].stats;
        for ci in 0..cfgs.len() {
            let r = &rs[bi * cfgs.len() + ci];
            t.row(vec![
                b.clone(),
                r.variant_label.clone(),
                format!("{:.2}x", base.cycles as f64 / r.stats.cycles as f64),
                format!("{:.2}", r.stats.switches as f64 / base.switches.max(1) as f64),
                format!("{:.1}", r.stats.ctx_ops_per_switch()),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn aggregation_reduces_switches_on_stream() {
        let opts = FigOpts { scale: Scale::Small, only: vec!["stream".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("+aggregation"));
    }
}
