//! Fig. 13: dynamic instruction count normalized to serial (the control
//! cost of coroutine codegen) at 100 ns latency. Paper averages:
//! CoroAMU-S 6.70x, CoroAMU-D 5.98x, CoroAMU-Full 3.91x.

use super::FigOpts;
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::{lookup, RunRequest};
use crate::util::table::{geomean, Table};
use anyhow::Result;

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let variants = [
        (Variant::Serial, 1usize),
        (Variant::CoroAmuS, 64),
        (Variant::CoroAmuD, 96),
        (Variant::CoroAmuFull, 96),
    ];
    let mut matrix = Vec::new();
    for b in opts.bench_names() {
        for (v, tasks) in variants {
            matrix.push(
                RunRequest::new(b.clone(), v)
                    .tasks(tasks)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key("100"),
            );
        }
    }
    let rs = grid::fetch(SimConfig::nh_g().with_far_latency_ns(100.0), &matrix, opts.threads)?;
    let mut t = Table::new(
        "Fig 13: dynamic instruction expansion vs serial @100ns (paper avg: S 6.70x, D 5.98x, Full 3.91x)",
        &["bench", "CoroAMU-S", "CoroAMU-D", "CoroAMU-Full"],
    );
    let mut cols: [Vec<f64>; 3] = Default::default();
    for b in opts.bench_names() {
        let base = lookup(&rs, &b, Variant::Serial, "100").unwrap().stats.dyn_instrs as f64;
        let mut row = vec![b.clone()];
        for (i, v) in [Variant::CoroAmuS, Variant::CoroAmuD, Variant::CoroAmuFull].iter().enumerate() {
            let e = lookup(&rs, &b, *v, "100").unwrap().stats.dyn_instrs as f64 / base;
            cols[i].push(e);
            row.push(format!("{e:.2}x"));
        }
        t.row(row);
    }
    t.row(vec![
        "geomean".into(),
        format!("{:.2}x", geomean(&cols[0])),
        format!("{:.2}x", geomean(&cols[1])),
        format!("{:.2}x", geomean(&cols[2])),
    ]);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn fig13_tiny() {
        let opts = FigOpts { scale: Scale::Tiny, only: vec!["stream".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        assert!(ts[0].render().contains("geomean"));
    }
}
