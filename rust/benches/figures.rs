//! End-to-end benchmarks: one timing entry per paper table/figure, each
//! regenerating the artifact at Small scale (Full scale via
//! `coroamu report --scale full`). The printed tables ARE the paper rows;
//! the timings document the cost of regenerating each.
//!
//! Run: `cargo bench --offline -- fig12` (or any figure filter).

use coroamu::benchmarks::Scale;
use coroamu::config::SimConfig;
use coroamu::harness::{self, FigOpts};
use coroamu::util::benchkit::Bench;

fn main() {
    let mut b = Bench::from_env();
    b.warmup_iters = 0;
    b.measure_iters = if std::env::var("COROAMU_BENCH_FAST").is_ok() { 1 } else { 2 };

    println!("== paper-artifact regeneration benchmarks (Small scale) ==\n");

    if b.enabled("table1") {
        SimConfig::nh_g().table1().print();
        b.run("table1", "row", || 1.0);
    }
    if b.enabled("table2") {
        coroamu::benchmarks::table2().print();
        b.run("table2", "row", || 1.0);
    }

    for fig in harness::ALL_FIGURES {
        let name = format!("fig{fig:02}");
        if !b.enabled(&name) {
            continue;
        }
        let opts = FigOpts { scale: Scale::Small, threads: 1, seed: 42, only: vec![] };
        // Print the tables once (the artifact), then time regeneration.
        match harness::figure(fig, &opts) {
            Ok(tables) => {
                for t in &tables {
                    t.print();
                }
                b.run(&name, "table", || {
                    let ts = harness::figure(fig, &opts).expect("figure");
                    ts.len() as f64
                });
            }
            Err(e) => panic!("figure {fig}: {e:#}"),
        }
    }
    b.finish();
}
