//! Micro-benchmarks of the simulator substrate itself (the L3 hot path):
//! per-sweep-point simulated MIPS (decode-once vs reference interpreter),
//! interpreter throughput per variant, cache-model probe rate, predictor
//! update rate. The `sim_mips/*` before/after numbers are recorded in
//! BENCH_sim.json at the repo root.
//!
//! Run: `cargo bench --offline` (filter: `cargo bench -- sim_mips`).

use coroamu::benchmarks::{self, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::sim::{self, MemImage};
use coroamu::util::benchkit::Bench;
use coroamu::util::rng::Rng;

/// Simulated-MIPS per sweep point, before/after this repo's decode-once
/// pipeline. Both sides run the complete per-point work the engine
/// performs in a sweep (kernel through the compile cache, link, simulate,
/// native-oracle check):
///
/// * `reference` — the pre-change shape: the benchmark instance (dataset
///   synthesis + oracle precomputation) is rebuilt for every point and
///   the program runs on the tree-walking reference interpreter.
/// * `decoded` — the current engine path: dataset restored from the
///   copy-on-write cache, program run on the decode-once interpreter.
///
/// The throughput metric is simulated dynamic instructions per
/// wall-second (printed as M instr/s == simulated MIPS); results land in
/// BENCH_sim.json at the repo root.
fn sim_mips(b: &mut Bench, bench_name: &str, variant: Variant) {
    let scale = Scale::Small;
    let seed = 42u64;

    let dec_name = format!("sim_mips/{}/{}/decoded", bench_name, variant.label());
    if b.enabled(&dec_name) {
        let engine = Engine::new(SimConfig::nh_g());
        b.run(&dec_name, "instr", || {
            let req = RunRequest::new(bench_name, variant).scale(scale).seed(seed);
            let r = engine.run(req).unwrap();
            r.stats.dyn_instrs as f64
        });
    }

    let ref_name = format!("sim_mips/{}/{}/reference", bench_name, variant.label());
    if b.enabled(&ref_name) {
        let engine = Engine::new(SimConfig::nh_g());
        let cfg = engine.config().clone();
        b.run(&ref_name, "instr", || {
            let bench = benchmarks::by_name(bench_name).unwrap();
            let inst = bench.instance(scale, seed).unwrap();
            let prepared = engine
                .prepare_kernel(&inst.kernel, &variant.opts(inst.default_tasks))
                .unwrap();
            let mut prog = sim::link(&cfg, &prepared.ck, inst.mem, &inst.params);
            let stats = sim::run_reference(&cfg, &mut prog).unwrap();
            (inst.check)(&prog.mem).unwrap();
            stats.dyn_instrs as f64
        });
    }
}

/// Speedup summary + BENCH_sim.json at the repo root.
fn record_sim_mips(b: &Bench) {
    let group = b.subset("sim_mips/");
    if group.samples.is_empty() {
        return;
    }
    for s in &group.samples {
        let Some(rest) = s.name.strip_suffix("/decoded") else { continue };
        let refname = format!("{rest}/reference");
        let (Some((dec, _)), Some((rf, _))) = (
            s.throughput,
            group.samples.iter().find(|r| r.name == refname).and_then(|r| r.throughput),
        ) else {
            continue;
        };
        println!(
            "speedup {:<38} {:.2}x  ({:.2} -> {:.2} simulated MIPS)",
            rest.trim_start_matches("sim_mips/"),
            dec / rf,
            rf / 1e6,
            dec / 1e6
        );
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    match group.write_json(&path) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}

fn interp_throughput(b: &mut Bench, bench_name: &str, variant: Variant) {
    let name = format!("interp/{}/{}", bench_name, variant.label());
    if !b.enabled(&name) {
        return;
    }
    // One engine session per entry: the first iteration compiles, the
    // rest measure pure link+simulate throughput through the kernel cache.
    let engine = Engine::new(SimConfig::nh_g());
    b.run(&name, "instr", || {
        let req = RunRequest::new(bench_name, variant).tasks(64).scale(Scale::Small);
        let r = engine.run(req).unwrap();
        r.stats.dyn_instrs as f64
    });
}

fn cache_probe_rate(b: &mut Bench) {
    use coroamu::sim::memsys::{AccessKind, MemSys};
    let cfg = SimConfig::nh_g();
    b.run("cache/probe_mixed", "access", || {
        let mut ms = MemSys::new(&cfg);
        let mut rng = Rng::new(1);
        let n = 200_000u64;
        let mut t = 0;
        for _ in 0..n {
            let addr = 0x8000_0000u64 + (rng.below(1 << 22)) * 8;
            t = ms.access(addr, coroamu::ir::AddrSpace::Remote, AccessKind::Load, t).saturating_sub(100);
        }
        n as f64
    });
}

fn bpu_update_rate(b: &mut Bench) {
    use coroamu::sim::bpu::Tage;
    let cfg = SimConfig::nh_g();
    b.run("bpu/tage_update", "branch", || {
        let mut t = Tage::new(&cfg.bpu);
        let mut rng = Rng::new(2);
        let n = 500_000u64;
        for i in 0..n {
            t.predict_and_update(i & 63, rng.below(10) != 0);
        }
        n as f64
    });
}

fn mem_image_rw(b: &mut Bench) {
    use coroamu::ir::{AddrSpace, Width};
    b.run("mem/rw8", "op", || {
        let mut m = MemImage::new();
        let len = 1u64 << 20;
        let base = m.alloc("x", AddrSpace::Remote, len);
        let n = 200_000u64;
        for i in 0..n {
            let a = base + ((i * 64) % (len - 8)) & !7;
            let v = m.read(a, Width::W8).unwrap();
            m.write(a, Width::W8, v + 1).unwrap();
        }
        2.0 * n as f64
    });
}

fn main() {
    let mut b = Bench::from_env();
    println!("== simulator substrate micro-benchmarks ==");
    sim_mips(&mut b, "gups", Variant::Serial);
    sim_mips(&mut b, "gups", Variant::CoroAmuFull);
    sim_mips(&mut b, "bfs", Variant::CoroAmuFull);
    interp_throughput(&mut b, "gups", Variant::Serial);
    interp_throughput(&mut b, "gups", Variant::CoroAmuFull);
    interp_throughput(&mut b, "bs", Variant::CoroAmuD);
    interp_throughput(&mut b, "stream", Variant::CoroAmuS);
    cache_probe_rate(&mut b);
    bpu_update_rate(&mut b);
    mem_image_rw(&mut b);
    b.finish();
    record_sim_mips(&b);
}
