//! Micro-benchmarks of the simulator substrate itself (the L3 hot path):
//! per-sweep-point simulated MIPS (decode-once vs reference interpreter),
//! interpreter throughput per variant, cache-model probe rate, predictor
//! update rate. The `sim_mips/*` before/after numbers are recorded in
//! BENCH_sim.json at the repo root.
//!
//! Run: `cargo bench --offline` (filter: `cargo bench -- sim_mips`).

use coroamu::benchmarks::{self, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::sim::{self, MemImage};
use coroamu::util::benchkit::Bench;
use coroamu::util::rng::Rng;

/// Simulated-MIPS per sweep point, before/after this repo's execution
/// pipeline work. Four rows per (benchmark, variant):
///
/// * `reference` — the pre-decode-once shape: the benchmark instance
///   (dataset synthesis + oracle precomputation) is rebuilt for every
///   point and the program runs on the tree-walking reference
///   interpreter.
/// * `decoded` — the engine steady state: dataset restored from the
///   copy-on-write cache, program run on the decode-once interpreter
///   with superop fusion (the session default).
/// * `decoded-fused` / `decoded-unfused` — interpreter-only columns:
///   identical per-iteration work (COW snapshot → link → simulate),
///   differing only in the decode-time fusion knob. Their ratio is the
///   superop win in isolation; CI fails if it regresses below 1x on
///   GUPS (see [`record_sim_mips`]).
///
/// The throughput metric is simulated dynamic instructions per
/// wall-second (printed as M instr/s == simulated MIPS); results land in
/// BENCH_sim.json at the repo root.
fn sim_mips(b: &mut Bench, bench_name: &str, variant: Variant) {
    let scale = Scale::Small;
    let seed = 42u64;

    let dec_name = format!("sim_mips/{}/{}/decoded", bench_name, variant.label());
    if b.enabled(&dec_name) {
        let engine = Engine::new(SimConfig::nh_g());
        b.run(&dec_name, "instr", || {
            let req = RunRequest::new(bench_name, variant).scale(scale).seed(seed);
            let r = engine.run(req).unwrap();
            r.stats.dyn_instrs as f64
        });
    }

    let fused_name = format!("sim_mips/{}/{}/decoded-fused", bench_name, variant.label());
    let unfused_name = format!("sim_mips/{}/{}/decoded-unfused", bench_name, variant.label());
    if b.enabled(&fused_name) || b.enabled(&unfused_name) {
        let engine = Engine::new(SimConfig::nh_g());
        let bench = benchmarks::by_name(bench_name).unwrap();
        let inst = bench.instance(scale, seed).unwrap();
        let prepared = engine
            .prepare_kernel(&inst.kernel, &variant.opts(inst.default_tasks))
            .unwrap();
        let mem = inst.mem;
        let params = inst.params.clone();
        for (name, fuse) in [(&fused_name, true), (&unfused_name, false)] {
            if !b.enabled(name) {
                continue;
            }
            let cfg = SimConfig::nh_g().with_fuse(fuse);
            b.run(name, "instr", || {
                let mut prog = sim::link(&cfg, &prepared.ck, mem.snapshot(), &params);
                sim::run(&cfg, &mut prog).unwrap().dyn_instrs as f64
            });
        }
    }

    let ref_name = format!("sim_mips/{}/{}/reference", bench_name, variant.label());
    if b.enabled(&ref_name) {
        let engine = Engine::new(SimConfig::nh_g());
        let cfg = engine.config().clone();
        b.run(&ref_name, "instr", || {
            let bench = benchmarks::by_name(bench_name).unwrap();
            let inst = bench.instance(scale, seed).unwrap();
            let prepared = engine
                .prepare_kernel(&inst.kernel, &variant.opts(inst.default_tasks))
                .unwrap();
            let mut prog = sim::link(&cfg, &prepared.ck, inst.mem, &inst.params);
            let stats = sim::run_reference(&cfg, &mut prog).unwrap();
            (inst.check)(&prog.mem).unwrap();
            stats.dyn_instrs as f64
        });
    }
}

/// Speedup summary + BENCH_sim.json at the repo root. Returns false if
/// the release-mode fusion guard tripped: decoded-fused must not
/// regress below decoded-unfused on GUPS (3% noise floor).
fn record_sim_mips(b: &Bench) -> bool {
    let group = b.subset("sim_mips/");
    if group.samples.is_empty() {
        return true;
    }
    let rate = |name: &str| -> Option<f64> {
        group.samples.iter().find(|r| r.name == name).and_then(|r| r.throughput).map(|(v, _)| v)
    };
    for s in &group.samples {
        let Some(rest) = s.name.strip_suffix("/decoded") else { continue };
        let (Some((dec, _)), Some(rf)) = (s.throughput, rate(&format!("{rest}/reference"))) else {
            continue;
        };
        println!(
            "speedup {:<38} {:.2}x  ({:.2} -> {:.2} simulated MIPS)",
            rest.trim_start_matches("sim_mips/"),
            dec / rf,
            rf / 1e6,
            dec / 1e6
        );
    }
    let mut ok = true;
    for s in &group.samples {
        let Some(rest) = s.name.strip_suffix("/decoded-fused") else { continue };
        let Some(su) = group.samples.iter().find(|r| r.name == format!("{rest}/decoded-unfused"))
        else {
            continue;
        };
        let (Some((fused, _)), Some((unfused, _))) = (s.throughput, su.throughput) else {
            continue;
        };
        println!(
            "fusion  {:<38} {:.2}x  ({:.2} -> {:.2} simulated MIPS)",
            rest.trim_start_matches("sim_mips/"),
            fused / unfused,
            unfused / 1e6,
            fused / 1e6
        );
        // Release-mode guard against fusion pessimization on the
        // headline kernel (debug builds are too noisy to gate on).
        // Gate on best-of-iteration throughput, not the mean: one noisy
        // outlier on a loaded CI runner must not fail the build.
        let fused_best = fused * s.mean_ns / s.min_ns.max(1.0);
        let unfused_best = unfused * su.mean_ns / su.min_ns.max(1.0);
        if rest.contains("/gups/") && !cfg!(debug_assertions) && fused_best < unfused_best * 0.97 {
            eprintln!(
                "FAIL: superop fusion regresses GUPS: {:.2} fused vs {:.2} unfused simulated MIPS (best-of)",
                fused_best / 1e6,
                unfused_best / 1e6
            );
            ok = false;
        }
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    match group.write_json(&path) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
    ok
}

/// Per-fabric decoded-MIPS columns (`sim_mips/fabric/<label>/...`, so
/// the CI `cargo bench -- sim_mips` smoke runs them and the regression
/// gate treats them like any other decoded row; baselines recorded
/// before the fabric subsystem simply skip them as new rows). The fabric
/// is a simulate-time knob, so each row is one engine session with the
/// backend baked into the config — what a fabric-axis figure sweep pays
/// per point.
fn fabric_mips(b: &mut Bench) {
    use coroamu::sim::fabric::FabricKind;
    for f in FabricKind::ALL {
        let name = format!("sim_mips/fabric/{}/gups/decoded", f.label());
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g().with_fabric(f));
        b.run(&name, "instr", || {
            let req = RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small).seed(42);
            engine.run(req).unwrap().stats.dyn_instrs as f64
        });
    }
}

/// Per-cluster-size decoded-MIPS columns
/// (`sim_mips/cluster/<cores>c/gups/decoded`), so the CI
/// `cargo bench -- sim_mips` smoke runs them and the regression gate
/// treats them like any other decoded row; baselines recorded before
/// the cluster subsystem simply skip them as new rows. Core count is a
/// simulate-time knob: each row reuses one engine session's kernel +
/// dataset caches, and the metric is *aggregate* simulated instructions
/// per wall-second — an n-core row simulates n times the work of the
/// single-core row, so the column doubles as a cost model for
/// `report --cluster` sweep points.
fn cluster_mips(b: &mut Bench) {
    for cores in [2u32, 4] {
        let name = format!("sim_mips/cluster/{cores}c/gups/decoded");
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g());
        b.run(&name, "instr", || {
            let req = RunRequest::new("gups", Variant::CoroAmuFull)
                .scale(Scale::Small)
                .seed(42)
                .cores(cores);
            engine.run(req).unwrap().stats.dyn_instrs as f64
        });
    }
}

/// Per-fault-intensity decoded-MIPS columns
/// (`sim_mips/faults/<spec>/gups/decoded`), so the CI
/// `cargo bench -- sim_mips` smoke runs them and the regression gate
/// treats them like any other decoded row; baselines recorded before the
/// fault subsystem simply skip them as new rows. Fault injection is a
/// simulate-time knob on the fabric decorator: each row is one engine
/// session with the preset baked into the config, and the column prices
/// what a `report --faults` chaos-sweep point costs — the retry/backoff
/// loop runs inside `FaultyFabric::issue`, so its wall-clock overhead is
/// exactly what this row measures.
fn faults_mips(b: &mut Bench) {
    use coroamu::sim::faults::FaultConfig;
    for spec in [FaultConfig::mild(), FaultConfig::heavy()] {
        let name = format!("sim_mips/faults/{}/gups/decoded", spec.label());
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g().with_faults(spec));
        b.run(&name, "instr", || {
            let req = RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small).seed(42);
            engine.run(req).unwrap().stats.dyn_instrs as f64
        });
    }
}

/// Per-offered-load decoded-MIPS columns
/// (`sim_mips/service/<spec>/gups/decoded`), so the CI
/// `cargo bench -- sim_mips` smoke runs them and the regression gate
/// treats them like any other decoded row; baselines recorded before
/// the service subsystem simply skip them as new rows. The open-loop
/// replay is a simulate-time pass over the finished batch run, so each
/// row prices what a `report --service` sweep point costs — the batch
/// simulation plus the deterministic queueing replay at that load.
fn service_mips(b: &mut Bench) {
    use coroamu::sim::service::ServiceConfig;
    for spec in [ServiceConfig::steady(), ServiceConfig::overload()] {
        let name = format!("sim_mips/service/{}/gups/decoded", spec.label());
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g().with_service(spec));
        b.run(&name, "instr", || {
            let req = RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small).seed(42);
            engine.run(req).unwrap().stats.dyn_instrs as f64
        });
    }
}

/// Tracing-overhead columns (`sim_mips/trace/{off,on}/gups/decoded`),
/// so the CI `cargo bench -- sim_mips` smoke runs them and the
/// regression gate treats them like any other decoded row; baselines
/// recorded before the trace subsystem simply skip them as new rows.
/// `off` is the default session re-measured next to `on` so the pair
/// shares one machine state — their ratio is the full price of the
/// bounded event ring + stall-attribution bookkeeping, and the `off`
/// row doubles as a canary: it must track the plain decoded row because
/// the off path constructs no tracer at all.
fn trace_mips(b: &mut Bench) {
    use coroamu::sim::trace::TraceConfig;
    for (tag, tc) in [("off", TraceConfig::off()), ("on", TraceConfig::on())] {
        let name = format!("sim_mips/trace/{tag}/gups/decoded");
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g().with_trace(tc));
        b.run(&name, "instr", || {
            let req = RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small).seed(42);
            engine.run(req).unwrap().stats.dyn_instrs as f64
        });
    }
}

/// Sweep-store columns (`sim_mips/store/{cold,warm}/gups`), so the CI
/// `cargo bench -- sim_mips` smoke runs them and the regression gate
/// treats them like any other decoded row; baselines recorded before the
/// store subsystem simply skip them as new rows. `cold` prices a
/// store-attached sweep that must simulate and persist every cell (the
/// store is emptied before each iteration); `warm` prices the planner
/// serving the same matrix entirely from disk — the `coroamu sweep` /
/// `report` steady state, which should be orders of magnitude cheaper.
fn store_mips(b: &mut Bench) {
    use coroamu::engine::store::Store;
    let matrix: Vec<RunRequest> = [150.0, 300.0, 600.0]
        .iter()
        .map(|l| {
            RunRequest::new("gups", Variant::CoroAmuFull)
                .scale(Scale::Small)
                .seed(42)
                .latency_ns(*l)
                .key(format!("{l}"))
        })
        .collect();
    let dir = std::env::temp_dir().join(format!("coroamu-bench-store-{}", std::process::id()));

    let cold_name = "sim_mips/store/cold/gups";
    if b.enabled(cold_name) {
        let engine = Engine::new(SimConfig::nh_g()).with_store(Store::open(&dir).unwrap());
        b.run(cold_name, "instr", || {
            for p in std::fs::read_dir(&dir).unwrap().flatten() {
                std::fs::remove_file(p.path()).unwrap();
            }
            let rs = engine.sweep(&matrix, 1).unwrap();
            rs.iter().map(|r| r.stats.dyn_instrs as f64).sum()
        });
    }

    let warm_name = "sim_mips/store/warm/gups";
    if b.enabled(warm_name) {
        let engine = Engine::new(SimConfig::nh_g()).with_store(Store::open(&dir).unwrap());
        engine.sweep(&matrix, 1).unwrap(); // prepopulate every cell
        b.run(warm_name, "instr", || {
            let rs = engine.sweep(&matrix, 1).unwrap();
            assert!(rs.iter().all(|r| r.store_hit), "warm row must be all store hits");
            rs.iter().map(|r| r.stats.dyn_instrs as f64).sum()
        });
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance sweep as a throughput row: {fifo, arrival, batched,
/// latency} x {200, 800} ns on GUPS/CoroAMU-Full through one engine
/// session (policy and latency are simulate-time, so the whole matrix is
/// one compile + one dataset build). Plus one row per policy so a policy
/// whose scheduling work regresses interpreter throughput is visible.
fn sched_policy_sweep(b: &mut Bench) {
    use coroamu::sim::sched::SchedPolicyKind;
    let matrix_name = "sched/sweep/gups/CoroAMU-Full";
    if b.enabled(matrix_name) {
        let engine = Engine::new(SimConfig::nh_g());
        b.run(matrix_name, "instr", || {
            let mut matrix = Vec::new();
            for p in SchedPolicyKind::ALL {
                for lat in [200.0, 800.0] {
                    matrix.push(
                        RunRequest::new("gups", Variant::CoroAmuFull)
                            .scale(Scale::Small)
                            .latency_ns(lat)
                            .policy(p)
                            .key(format!("{lat}/{}", p.label())),
                    );
                }
            }
            let rs = engine.sweep(&matrix, 4).unwrap();
            rs.iter().map(|r| r.stats.dyn_instrs as f64).sum()
        });
    }
    for p in SchedPolicyKind::ALL {
        let name = format!("sched/policy/{}/gups", p.label());
        if !b.enabled(&name) {
            continue;
        }
        let engine = Engine::new(SimConfig::nh_g().with_sched_policy(p));
        b.run(&name, "instr", || {
            let r = engine
                .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small))
                .unwrap();
            r.stats.dyn_instrs as f64
        });
    }
}

fn interp_throughput(b: &mut Bench, bench_name: &str, variant: Variant) {
    let name = format!("interp/{}/{}", bench_name, variant.label());
    if !b.enabled(&name) {
        return;
    }
    // One engine session per entry: the first iteration compiles, the
    // rest measure pure link+simulate throughput through the kernel cache.
    let engine = Engine::new(SimConfig::nh_g());
    b.run(&name, "instr", || {
        let req = RunRequest::new(bench_name, variant).tasks(64).scale(Scale::Small);
        let r = engine.run(req).unwrap();
        r.stats.dyn_instrs as f64
    });
}

fn cache_probe_rate(b: &mut Bench) {
    use coroamu::sim::memsys::{AccessKind, MemSys};
    let cfg = SimConfig::nh_g();
    b.run("cache/probe_mixed", "access", || {
        let mut ms = MemSys::new(&cfg);
        let mut rng = Rng::new(1);
        let n = 200_000u64;
        let mut t = 0;
        for _ in 0..n {
            let addr = 0x8000_0000u64 + (rng.below(1 << 22)) * 8;
            t = ms.access(addr, coroamu::ir::AddrSpace::Remote, AccessKind::Load, t).saturating_sub(100);
        }
        n as f64
    });
}

fn bpu_update_rate(b: &mut Bench) {
    use coroamu::sim::bpu::Tage;
    let cfg = SimConfig::nh_g();
    b.run("bpu/tage_update", "branch", || {
        let mut t = Tage::new(&cfg.bpu);
        let mut rng = Rng::new(2);
        let n = 500_000u64;
        for i in 0..n {
            t.predict_and_update(i & 63, rng.below(10) != 0);
        }
        n as f64
    });
}

fn mem_image_rw(b: &mut Bench) {
    use coroamu::ir::{AddrSpace, Width};
    b.run("mem/rw8", "op", || {
        let mut m = MemImage::new();
        let len = 1u64 << 20;
        let base = m.alloc("x", AddrSpace::Remote, len);
        let n = 200_000u64;
        for i in 0..n {
            let a = base + ((i * 64) % (len - 8)) & !7;
            let v = m.read(a, Width::W8).unwrap();
            m.write(a, Width::W8, v + 1).unwrap();
        }
        2.0 * n as f64
    });
}

fn main() {
    let mut b = Bench::from_env();
    println!("== simulator substrate micro-benchmarks ==");
    sim_mips(&mut b, "gups", Variant::Serial);
    sim_mips(&mut b, "gups", Variant::CoroAmuFull);
    sim_mips(&mut b, "bfs", Variant::CoroAmuFull);
    // Irregular-workload coverage: hash-join probe (dependent hashing +
    // bucket walk) and an MCF-style pointer chase (serialized loads).
    sim_mips(&mut b, "hj", Variant::CoroAmuFull);
    sim_mips(&mut b, "mcf", Variant::Serial);
    fabric_mips(&mut b);
    cluster_mips(&mut b);
    faults_mips(&mut b);
    service_mips(&mut b);
    trace_mips(&mut b);
    store_mips(&mut b);
    sched_policy_sweep(&mut b);
    interp_throughput(&mut b, "gups", Variant::Serial);
    interp_throughput(&mut b, "gups", Variant::CoroAmuFull);
    interp_throughput(&mut b, "bs", Variant::CoroAmuD);
    interp_throughput(&mut b, "stream", Variant::CoroAmuS);
    cache_probe_rate(&mut b);
    bpu_update_rate(&mut b);
    mem_image_rw(&mut b);
    b.finish();
    if !record_sim_mips(&b) {
        std::process::exit(1);
    }
}
