//! Micro-benchmarks of the simulator substrate itself (the L3 hot path):
//! interpreter throughput per variant, cache-model probe rate, predictor
//! update rate. This is the §Perf instrumentation — before/after numbers
//! are recorded in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --offline` (filter: `cargo bench -- interp`).

use coroamu::benchmarks::Scale;
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::sim::MemImage;
use coroamu::util::benchkit::Bench;
use coroamu::util::rng::Rng;

fn interp_throughput(b: &mut Bench, bench_name: &str, variant: Variant) {
    let name = format!("interp/{}/{}", bench_name, variant.label());
    if !b.enabled(&name) {
        return;
    }
    // One engine session per entry: the first iteration compiles, the
    // rest measure pure link+simulate throughput through the kernel cache.
    let engine = Engine::new(SimConfig::nh_g());
    b.run(&name, "instr", || {
        let req = RunRequest::new(bench_name, variant).tasks(64).scale(Scale::Small);
        let r = engine.run(req).unwrap();
        r.stats.dyn_instrs as f64
    });
}

fn cache_probe_rate(b: &mut Bench) {
    use coroamu::sim::memsys::{AccessKind, MemSys};
    let cfg = SimConfig::nh_g();
    b.run("cache/probe_mixed", "access", || {
        let mut ms = MemSys::new(&cfg);
        let mut rng = Rng::new(1);
        let n = 200_000u64;
        let mut t = 0;
        for _ in 0..n {
            let addr = 0x8000_0000u64 + (rng.below(1 << 22)) * 8;
            t = ms.access(addr, coroamu::ir::AddrSpace::Remote, AccessKind::Load, t).saturating_sub(100);
        }
        n as f64
    });
}

fn bpu_update_rate(b: &mut Bench) {
    use coroamu::sim::bpu::Tage;
    let cfg = SimConfig::nh_g();
    b.run("bpu/tage_update", "branch", || {
        let mut t = Tage::new(&cfg.bpu);
        let mut rng = Rng::new(2);
        let n = 500_000u64;
        for i in 0..n {
            t.predict_and_update(i & 63, rng.below(10) != 0);
        }
        n as f64
    });
}

fn mem_image_rw(b: &mut Bench) {
    use coroamu::ir::{AddrSpace, Width};
    b.run("mem/rw8", "op", || {
        let mut m = MemImage::new();
        let len = 1u64 << 20;
        let base = m.alloc("x", AddrSpace::Remote, len);
        let n = 200_000u64;
        for i in 0..n {
            let a = base + ((i * 64) % (len - 8)) & !7;
            let v = m.read(a, Width::W8).unwrap();
            m.write(a, Width::W8, v + 1).unwrap();
        }
        2.0 * n as f64
    });
}

fn main() {
    let mut b = Bench::from_env();
    println!("== simulator substrate micro-benchmarks ==");
    interp_throughput(&mut b, "gups", Variant::Serial);
    interp_throughput(&mut b, "gups", Variant::CoroAmuFull);
    interp_throughput(&mut b, "bs", Variant::CoroAmuD);
    interp_throughput(&mut b, "stream", Variant::CoroAmuS);
    cache_probe_rate(&mut b);
    bpu_update_rate(&mut b);
    mem_image_rw(&mut b);
    b.finish();
}
