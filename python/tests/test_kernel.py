"""L1 correctness: Pallas kernels vs pure-numpy oracles.

Fixed-shape checks at the AOT artifact shapes, plus hypothesis sweeps over
sizes and data. This is the CORE correctness signal for the Python layers;
the Rust side re-validates the same artifacts through PJRT
(`coroamu oracle`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref
from compile.kernels.bs import bs_pallas
from compile.kernels.gups import gups_pallas
from compile.kernels.hj import hj_pallas
from compile.kernels.stream import stream_pallas
from compile import model


def test_mix64_pins_match_rust():
    for x, want in ref.MIX64_PINS.items():
        assert int(ref.mix64(np.uint64(x))) == want


# ---------------------------------------------------------------- GUPS

def test_gups_pallas_matches_ref_at_artifact_shape():
    rng = np.random.default_rng(0)
    table = rng.integers(0, 2**62, size=model.GUPS_TABLE, dtype=np.int64)
    out = np.asarray(gups_pallas(jnp.asarray(table), model.GUPS_N))
    np.testing.assert_array_equal(out, ref.gups_ref(table, model.GUPS_N))


@settings(max_examples=10, deadline=None)
@given(
    logk=st.integers(min_value=4, max_value=10),
    n=st.integers(min_value=1, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gups_pallas_matches_ref_swept(logk, n, seed):
    rng = np.random.default_rng(seed)
    table = rng.integers(0, 2**62, size=1 << logk, dtype=np.int64)
    out = np.asarray(gups_pallas(jnp.asarray(table), n))
    np.testing.assert_array_equal(out, ref.gups_ref(table, n))


# -------------------------------------------------------------- STREAM

def test_stream_pallas_matches_ref_at_artifact_shape():
    rng = np.random.default_rng(1)
    b = rng.random(model.STREAM_N)
    c = rng.random(model.STREAM_N)
    # XLA may fuse mul+add into an FMA: ULP-level tolerance.
    out = np.asarray(stream_pallas(jnp.asarray(b), jnp.asarray(c)))
    np.testing.assert_allclose(out, ref.stream_ref(b, c), rtol=1e-15)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([7, 64, 512, 1024, 1536, 4096]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_stream_pallas_matches_ref_swept(n, seed):
    rng = np.random.default_rng(seed)
    b = rng.random(n)
    c = rng.random(n)
    out = np.asarray(stream_pallas(jnp.asarray(b), jnp.asarray(c)))
    np.testing.assert_allclose(out, ref.stream_ref(b, c), rtol=1e-15)


# ------------------------------------------------------------------ BS

def _sorted_array(k):
    return (2 * np.arange(k, dtype=np.int64) + 1)


def test_bs_pallas_matches_ref_at_artifact_shape():
    arr = _sorted_array(model.BS_KEYS)
    out = np.asarray(bs_pallas(jnp.asarray(arr), model.BS_QUERIES))
    np.testing.assert_array_equal(out, ref.bs_ref(arr, model.BS_QUERIES))


@settings(max_examples=8, deadline=None)
@given(
    logk=st.integers(min_value=3, max_value=12),
    q=st.integers(min_value=1, max_value=128),
)
def test_bs_pallas_matches_ref_swept(logk, q):
    arr = _sorted_array(1 << logk)
    out = np.asarray(bs_pallas(jnp.asarray(arr), q))
    np.testing.assert_array_equal(out, ref.bs_ref(arr, q))


# ------------------------------------------------------------------ HJ

def _hj_case(nbuckets, ntuples, seed):
    rng = np.random.default_rng(seed)
    domain = nbuckets * 4
    build_keys = rng.integers(0, domain, size=2 * nbuckets, dtype=np.int64)
    flat = ref.build_table(nbuckets, build_keys)
    keys = rng.integers(0, domain, size=ntuples, dtype=np.int64)
    return flat, keys


def test_hj_pallas_matches_ref_at_artifact_shape():
    flat, keys = _hj_case(model.HJ_BUCKETS, model.HJ_TUPLES, 2)
    out = np.asarray(hj_pallas(jnp.asarray(flat), jnp.asarray(keys), model.HJ_BUCKETS - 1))
    assert out[0] == ref.hj_ref(flat, keys, model.HJ_BUCKETS - 1)


@settings(max_examples=6, deadline=None)
@given(
    logb=st.integers(min_value=3, max_value=8),
    t=st.integers(min_value=1, max_value=256),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hj_pallas_matches_ref_swept(logb, t, seed):
    flat, keys = _hj_case(1 << logb, t, seed)
    out = np.asarray(hj_pallas(jnp.asarray(flat), jnp.asarray(keys), (1 << logb) - 1))
    assert out[0] == ref.hj_ref(flat, keys, (1 << logb) - 1)


# --------------------------------------------------------------- model

def test_l2_models_trace_and_match_shapes():
    for name, (fn, specs) in model.MODELS.items():
        out_aval = jax.eval_shape(fn, *specs)
        assert isinstance(out_aval, tuple) and len(out_aval) == 1, name


def test_l2_gups_model_executes():
    rng = np.random.default_rng(3)
    table = rng.integers(0, 2**62, size=model.GUPS_TABLE, dtype=np.int64)
    (out,) = model.gups_model(jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(out), ref.gups_ref(table, model.GUPS_N))
