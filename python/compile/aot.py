"""AOT driver: lower every L2 model to HLO **text** in artifacts/.

HLO text (NOT ``lowered.compiler_ir('hlo')``-proto serialization) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and DESIGN.md.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, specs) in MODELS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: single-artifact dir inference")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    build_all(out_dir)


if __name__ == "__main__":
    main()
