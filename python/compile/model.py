"""L2: the JAX golden models, one jitted function per workload, calling
the L1 Pallas kernels. These are AOT-lowered by ``aot.py`` to HLO text and
executed from the Rust coordinator via PJRT — Python never runs at
simulation time.

Shapes are fixed to ``rust/src/benchmarks/mod.rs::oracle_shapes`` so the
Rust oracle check (`coroamu oracle`) can feed Tiny-scale instances through
the artifacts.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.bs import bs_pallas
from .kernels.gups import gups_pallas
from .kernels.hj import hj_pallas
from .kernels.stream import stream_pallas

# Mirror of rust oracle_shapes.
GUPS_TABLE = 4096
GUPS_N = 512
STREAM_N = 4096
BS_KEYS = 4096
BS_QUERIES = 256
HJ_BUCKETS = 512
HJ_TUPLES = 1024
# Bucket memory includes the overflow pool (see hj.rs::build_table):
HJ_BUCKET_WORDS = (HJ_BUCKETS + HJ_BUCKETS // 2 + 4) * 8


def gups_model(table):
    """int64[GUPS_TABLE] -> (int64[GUPS_TABLE],)"""
    return (gups_pallas(table, GUPS_N),)


def stream_model(b, c):
    """f64[STREAM_N] x f64[STREAM_N] -> (f64[STREAM_N],)"""
    return (stream_pallas(b, c),)


def bs_model(sorted_array):
    """int64[BS_KEYS] -> (int64[BS_QUERIES],)"""
    return (bs_pallas(sorted_array, BS_QUERIES),)


def hj_model(buckets_flat, keys):
    """int64[HJ_BUCKET_WORDS] x int64[HJ_TUPLES] -> (int64[1],)"""
    return (hj_pallas(buckets_flat, keys, HJ_BUCKETS - 1),)


def model(b, c):
    """The default end-to-end artifact (`model.hlo.txt`): STREAM triad."""
    return stream_model(b, c)


#: name -> (fn, example argument shapes/dtypes)
MODELS = {
    "gups": (gups_model, [jax.ShapeDtypeStruct((GUPS_TABLE,), jnp.int64)]),
    "stream": (
        stream_model,
        [jax.ShapeDtypeStruct((STREAM_N,), jnp.float64), jax.ShapeDtypeStruct((STREAM_N,), jnp.float64)],
    ),
    "bs": (bs_model, [jax.ShapeDtypeStruct((BS_KEYS,), jnp.int64)]),
    "hj": (
        hj_model,
        [jax.ShapeDtypeStruct((HJ_BUCKET_WORDS,), jnp.int64), jax.ShapeDtypeStruct((HJ_TUPLES,), jnp.int64)],
    ),
    "model": (
        model,
        [jax.ShapeDtypeStruct((STREAM_N,), jnp.float64), jax.ShapeDtypeStruct((STREAM_N,), jnp.float64)],
    ),
}
