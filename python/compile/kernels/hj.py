"""L1 Pallas kernel: hash-join probe golden model (paper Listing 1).

Walks each probe key's bucket chain (8-word buckets {cnt, next, k0..k3})
with a bounded fori_loop + validity masking, accumulating match counts.
Uses the same mix64 hash as the Rust simulator (pinned constants).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_CHAIN = 32
WORDS = 8


def _mix64(x):
    x = x.astype(jnp.uint64)
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(0xFF51AFD7ED558CCD)
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(0xC4CEB9FE1A85EC53)
    x = x ^ (x >> jnp.uint64(33))
    return x


def _kernel(num_keys, bmask, buckets_ref, keys_ref, o_ref):
    def per_key(t, total):
        key = pl.load(keys_ref, (pl.dslice(t.astype(jnp.int64), 1),))[0]
        b0 = (_mix64(key) & jnp.uint64(bmask)).astype(jnp.int64)

        def chain(_, carry):
            b, acc = carry
            valid = b >= 0
            bi = jnp.where(valid, b, 0)
            base = bi * WORDS
            rec = pl.load(buckets_ref, (pl.dslice(base, WORDS),))
            cnt, nxt = rec[0], rec[1]
            m = jnp.int64(0)
            for j in range(4):
                m = m + ((jnp.int64(j) < cnt) & (rec[2 + j] == key)).astype(jnp.int64)
            acc = acc + jnp.where(valid, m, 0)
            b = jnp.where(valid, nxt, jnp.int64(-1))
            return (b, acc)

        _, total = jax.lax.fori_loop(0, MAX_CHAIN, chain, (b0, total))
        return total

    total = jax.lax.fori_loop(0, num_keys, per_key, jnp.int64(0))
    o_ref[...] = total[None]


def hj_pallas(buckets_flat, keys, bmask):
    """buckets_flat: int64[total*8]; keys: int64[T] -> int64[1] matches."""
    return pl.pallas_call(
        lambda b_ref, k_ref, o_ref: _kernel(keys.shape[0], bmask, b_ref, k_ref, o_ref),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int64),
        interpret=True,
    )(buckets_flat, keys)
