"""L1 Pallas kernel: STREAM triad ``a = b + s*c`` (f64), tiled for VMEM.

The grid walks 512-element blocks; BlockSpec expresses the HBM->VMEM
streaming schedule (the TPU analogue of the paper's remote->SPM aloads).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SCALAR

BLOCK = 512


def _kernel(s, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + s * c_ref[...]


def stream_pallas(b, c, scalar=SCALAR):
    n = b.shape[0]
    if n % BLOCK == 0 and n >= BLOCK:
        grid = (n // BLOCK,)
        spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
        return pl.pallas_call(
            lambda br, cr, ar: _kernel(scalar, br, cr, ar),
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
            interpret=True,
        )(b, c)
    # Odd sizes (hypothesis sweeps): single block.
    return pl.pallas_call(
        lambda br, cr, ar: _kernel(scalar, br, cr, ar),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )(b, c)
