"""L1 Pallas kernel: GUPS scatter-update golden model.

Applies ``table[idx] += idx|1`` for ``idx = (i*PERM) & mask`` with a
sequential in-kernel update loop over the table held in a VMEM block
(interpret=True on CPU; on a real TPU the table block streams HBM->VMEM
through the BlockSpec). The pure-numpy oracle is ``ref.gups_ref``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PERM


def _kernel(num_updates, table_ref, o_ref):
    o_ref[...] = table_ref[...]
    mask = jnp.int64(o_ref.shape[0] - 1)

    def body(i, carry):
        idx = (i.astype(jnp.int64) * jnp.int64(PERM)) & mask
        v = pl.load(o_ref, (pl.dslice(idx, 1),))
        pl.store(o_ref, (pl.dslice(idx, 1),), v + (idx | jnp.int64(1)))
        return carry

    jax.lax.fori_loop(0, num_updates, body, 0)


def gups_pallas(table, num_updates):
    """table: int64[2^k] -> updated table (int64[2^k])."""
    return pl.pallas_call(
        lambda t_ref, o_ref: _kernel(num_updates, t_ref, o_ref),
        out_shape=jax.ShapeDtypeStruct(table.shape, jnp.int64),
        interpret=True,
    )(table)
