"""Pure-numpy golden references for the Pallas kernels.

These are the correctness oracles (deliberately implemented with different
algorithms than the Pallas kernels - e.g. vectorized searchsorted vs the
kernel's scalar bisection loop). Constants are pinned to the Rust side:

* ``mix64``  - rust/src/sim/interp.rs::mix64 (MurmurHash3 finalizer)
* ``PERM``   - rust/src/benchmarks/gups.rs::PERM
* ``QPERM``  - rust/src/benchmarks/bs.rs::QPERM
* ``SCALAR`` - rust/src/benchmarks/stream.rs::SCALAR
"""

import numpy as np

PERM = 0x9E3779B9
QPERM = 0x5851F42D
SCALAR = 3.0

# Pinned values asserted in rust (interp.rs::mix64_reference_values).
MIX64_PINS = {
    0: 0x0,
    1: 0xB456BCFC34C2CB2C,
    42: 0x810879608E4259CC,
    0xDEADBEEF: 0xD24BD59F862A1DAC,
}


def mix64(x):
    """MurmurHash3 finalizer over uint64 (vectorized)."""
    x = np.asarray(x).astype(np.uint64)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint64(33))
        x = x * np.uint64(0xFF51AFD7ED558CCD)
        x = x ^ (x >> np.uint64(33))
        x = x * np.uint64(0xC4CEB9FE1A85EC53)
        x = x ^ (x >> np.uint64(33))
    return x


def gups_ref(table, num_updates):
    """table[idx] += idx|1 for idx = (i*PERM) & mask, i in [0, N)."""
    table = np.asarray(table, dtype=np.int64).copy()
    mask = np.int64(table.shape[0] - 1)
    i = np.arange(num_updates, dtype=np.int64)
    idx = (i * np.int64(PERM)) & mask
    np.add.at(table, idx, idx | np.int64(1))
    return table


def stream_ref(b, c, scalar=SCALAR):
    return np.asarray(b, dtype=np.float64) + scalar * np.asarray(c, dtype=np.float64)


def bs_ref(sorted_array, num_queries):
    """Vectorized oracle via searchsorted (kernel uses scalar bisection)."""
    sorted_array = np.asarray(sorted_array, dtype=np.int64)
    kmask = np.int64(sorted_array.shape[0] - 1)
    q = (np.arange(num_queries, dtype=np.int64) * np.int64(QPERM)) & kmask
    targets = 2 * q + 1
    return np.searchsorted(sorted_array, targets, side="left").astype(np.int64)


def hj_ref(buckets_flat, keys, bmask):
    """Chain-walking probe count (python-loop oracle)."""
    buckets = np.asarray(buckets_flat, dtype=np.int64).reshape(-1, 8)
    total = 0
    for key in np.asarray(keys, dtype=np.int64):
        b = int(mix64(np.uint64(key)) & np.uint64(bmask))
        while b != -1:
            cnt = buckets[b, 0]
            for j in range(4):
                if j < cnt and buckets[b, 2 + j] == key:
                    total += 1
            b = int(buckets[b, 1])
    return np.int64(total)


def build_table(nbuckets, build_keys):
    """Host-side hash-table build - mirrors rust hj.rs::build_table."""
    words = 8
    total = nbuckets + nbuckets // 2 + 4
    flat = np.zeros(total * words, dtype=np.int64)
    for c in range(total):
        flat[c * words + 1] = -1
    next_free = nbuckets
    for k in np.asarray(build_keys, dtype=np.int64):
        bi = int(mix64(np.uint64(k)) & np.uint64(nbuckets - 1))
        while True:
            cnt = flat[bi * words]
            if cnt < 4:
                flat[bi * words + 2 + cnt] = k
                flat[bi * words] = cnt + 1
                break
            nxt = flat[bi * words + 1]
            if nxt == -1:
                assert next_free < total, "overflow pool exhausted"
                flat[bi * words + 1] = next_free
                bi = next_free
                next_free += 1
            else:
                bi = int(nxt)
    return flat
