"""L1 Pallas kernel: batched binary search golden model.

Scalar lo/hi bisection per query (the same algorithm the CoroIR kernel
runs), with the sorted array resident in a VMEM block. The oracle
(`ref.bs_ref`) instead uses vectorized searchsorted - algorithmic
diversity between kernel and reference.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QPERM


def _kernel(num_queries, steps, arr_ref, o_ref):
    kmask = jnp.int64(arr_ref.shape[0] - 1)

    def per_query(q, carry):
        q64 = q.astype(jnp.int64)
        target = 2 * ((q64 * jnp.int64(QPERM)) & kmask) + 1

        def step(_, lohi):
            lo, hi = lohi
            active = lo < hi
            mid = (lo + hi) >> 1
            v = pl.load(arr_ref, (pl.dslice(mid, 1),))[0]
            pred = v < target
            lo2 = jnp.where(active & pred, mid + 1, lo)
            hi2 = jnp.where(active & ~pred, mid, hi)
            return (lo2, hi2)

        lo, _ = jax.lax.fori_loop(0, steps, step, (jnp.int64(0), kmask))
        pl.store(o_ref, (pl.dslice(q64, 1),), lo[None])
        return carry

    jax.lax.fori_loop(0, num_queries, per_query, 0)


def bs_pallas(sorted_array, num_queries):
    k = sorted_array.shape[0]
    steps = max(1, (k - 1).bit_length())
    return pl.pallas_call(
        lambda a_ref, o_ref: _kernel(num_queries, steps, a_ref, o_ref),
        out_shape=jax.ShapeDtypeStruct((num_queries,), jnp.int64),
        interpret=True,
    )(sorted_array)
