#!/usr/bin/env python3
"""Validate a `coroamu trace` Chrome trace-event JSON export.

Checks that the file is what Perfetto / chrome://tracing will load:
valid JSON, a top-level object with a `traceEvents` list, every event
an object carrying a known `ph` with the fields that phase requires
(`M` metadata may omit `ts`; `X` slices need a non-negative `dur`),
and at least --min-events non-metadata events so an empty or
metadata-only export fails loudly instead of uploading as a green
artifact.

Usage:
  python3 ci/check_trace_json.py TRACE.json [--min-events 1]
"""

import argparse
import json
import sys

KNOWN_PH = {"X", "C", "i", "M"}


def fail(msg):
    print(f"ERROR: {msg}")
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace")
    ap.add_argument("--min-events", type=int, default=1)
    args = ap.parse_args()

    try:
        with open(args.trace, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {args.trace}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{args.trace} is not valid JSON: {e}")
    if not isinstance(doc, dict):
        fail(f"top level is {type(doc).__name__}, expected an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail(f"'traceEvents' is {type(events).__name__}, expected a list")

    payload = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"traceEvents[{i}] is {type(ev).__name__}, expected an object")
        ph = ev.get("ph")
        if ph not in KNOWN_PH:
            fail(f"traceEvents[{i}] has unknown ph {ph!r} (expected one of {sorted(KNOWN_PH)})")
        if not isinstance(ev.get("pid"), int):
            fail(f"traceEvents[{i}] ({ph}) lacks an integer 'pid'")
        if not isinstance(ev.get("name"), str):
            fail(f"traceEvents[{i}] ({ph}) lacks a string 'name'")
        if ph == "M":
            continue
        payload += 1
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            fail(f"traceEvents[{i}] ({ph} '{ev['name']}') lacks a non-negative integer 'ts'")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                fail(f"traceEvents[{i}] (X '{ev['name']}') lacks a non-negative integer 'dur'")

    if payload < args.min_events:
        fail(f"only {payload} non-metadata event(s), expected at least {args.min_events}")
    print(f"OK: {args.trace}: {payload} event(s) + {len(events) - payload} metadata record(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
