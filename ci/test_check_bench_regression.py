#!/usr/bin/env python3
"""Fixture tests for check_bench_regression.py.

Run: python3 ci/test_check_bench_regression.py

Pins the gate's contract on hostile input: malformed BENCH_sim.json
(invalid JSON, wrong-shape top level, non-list samples, non-object
sample entries, truncated writes) must exit 1 with a readable ERROR —
never a traceback, and never a silent "gate skipped" exit 0. Also pins
the healthy paths the workflows rely on: regressions past --fail-pct
fail, rows present on only one side (e.g. a fresh `sim_mips/faults/*`
group against a pre-faults baseline) never gate, and placeholder
baselines skip cleanly.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "check_bench_regression.py")


def doc(samples, mode="release"):
    return {"mode": mode, "samples": samples}


def row(name, rate):
    return {"name": name, "rate_per_s": rate}


class Gate(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)

    def path(self, name, content):
        p = os.path.join(self.tmp.name, name)
        with open(p, "w", encoding="utf-8") as f:
            f.write(content if isinstance(content, str) else json.dumps(content))
        return p

    def run_gate(self, baseline, fresh, *extra):
        return subprocess.run(
            [sys.executable, SCRIPT, baseline, fresh, *extra],
            capture_output=True, text=True)

    def assert_malformed(self, r, needle):
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("ERROR", r.stdout)
        self.assertIn(needle, r.stdout)
        self.assertNotIn("Traceback", r.stderr, "must fail cleanly, not crash")

    def test_truncated_json_is_an_error(self):
        base = self.path("base.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        fresh = self.path("fresh.json", '{"mode": "release", "samples": [{"na')
        self.assert_malformed(self.run_gate(base, fresh), "not valid JSON")

    def test_non_object_top_level_is_an_error(self):
        base = self.path("base.json", [1, 2, 3])
        fresh = self.path("fresh.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        self.assert_malformed(self.run_gate(base, fresh), "top level")

    def test_non_list_samples_is_an_error(self):
        base = self.path("base.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        fresh = self.path("fresh.json", {"mode": "release", "samples": "oops"})
        self.assert_malformed(self.run_gate(base, fresh), "'samples'")

    def test_non_object_sample_entry_is_an_error(self):
        base = self.path("base.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        fresh = self.path("fresh.json", {"mode": "release", "samples": ["oops"]})
        self.assert_malformed(self.run_gate(base, fresh), "samples[0]")

    def test_missing_fresh_measurement_is_an_error(self):
        base = self.path("base.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        r = self.run_gate(base, os.path.join(self.tmp.name, "nope.json"))
        self.assertEqual(r.returncode, 1, r.stdout)
        self.assertIn("ERROR", r.stdout)

    def test_within_tolerance_passes(self):
        name = "sim_mips/gups/CoroAMU-Full/decoded"
        base = self.path("base.json", doc([row(name, 1e8)]))
        fresh = self.path("fresh.json", doc([row(name, 0.99e8)]))
        r = self.run_gate(base, fresh)
        self.assertEqual(r.returncode, 0, r.stdout)
        self.assertIn("OK", r.stdout)

    def test_regression_past_fail_pct_fails(self):
        name = "sim_mips/faults/heavy/gups/decoded"
        base = self.path("base.json", doc([row(name, 1e8)]))
        fresh = self.path("fresh.json", doc([row(name, 0.5e8)]))
        r = self.run_gate(base, fresh)
        self.assertEqual(r.returncode, 1, r.stdout)
        self.assertIn("FAIL", r.stdout)

    def test_new_rows_are_reported_but_never_gate(self):
        # A fresh recording that grew the faults group against a
        # pre-faults baseline must pass: skip-if-absent, start gating
        # only once a baseline containing the rows is committed.
        old = "sim_mips/gups/CoroAMU-Full/decoded"
        new = "sim_mips/faults/heavy/gups/decoded"
        base = self.path("base.json", doc([row(old, 1e8)]))
        fresh = self.path("fresh.json", doc([row(old, 1e8), row(new, 1e6)]))
        r = self.run_gate(base, fresh)
        self.assertEqual(r.returncode, 0, r.stdout)
        self.assertIn("new row (not gated)", r.stdout)

    def test_placeholder_baseline_skips_the_gate(self):
        base = self.path("base.json", doc([]))
        fresh = self.path("fresh.json", doc([row("sim_mips/gups/CoroAMU-Full/decoded", 1e8)]))
        r = self.run_gate(base, fresh)
        self.assertEqual(r.returncode, 0, r.stdout)
        self.assertIn("NOTICE", r.stdout)


if __name__ == "__main__":
    unittest.main()
