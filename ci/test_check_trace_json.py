#!/usr/bin/env python3
"""Fixture tests for check_trace_json.py.

Run: python3 ci/test_check_trace_json.py

Pins the validator's contract on hostile input: malformed exports
(invalid JSON, wrong-shape top level, missing traceEvents, unknown ph,
events without pid/ts/dur) must exit 1 with a readable ERROR — never a
traceback — and a metadata-only or empty export must fail the
--min-events floor rather than upload as a green artifact. Healthy
exports in the shape `sim::trace::chrome_json` emits pass.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "check_trace_json.py")


def meta(pid=0):
    return {"ph": "M", "pid": pid, "name": "process_name",
            "args": {"name": f"core {pid}"}}


def slice_x(ts=10, dur=5, tid=3):
    return {"ph": "X", "pid": 0, "tid": tid, "ts": ts, "dur": dur,
            "name": "coro 3", "cat": "coro"}


def doc(events):
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"note": "test"}}


class Validator(unittest.TestCase):
    def setUp(self):
        self.tmp = tempfile.TemporaryDirectory()
        self.addCleanup(self.tmp.cleanup)

    def path(self, content):
        p = os.path.join(self.tmp.name, "trace.json")
        with open(p, "w", encoding="utf-8") as f:
            f.write(content if isinstance(content, str) else json.dumps(content))
        return p

    def run_check(self, path, *extra):
        return subprocess.run(
            [sys.executable, SCRIPT, path, *extra],
            capture_output=True, text=True)

    def assert_rejected(self, r, needle):
        self.assertEqual(r.returncode, 1, r.stdout + r.stderr)
        self.assertIn("ERROR", r.stdout)
        self.assertIn(needle, r.stdout)
        self.assertNotIn("Traceback", r.stderr, "must fail cleanly, not crash")

    def test_valid_export_passes(self):
        events = [meta(), slice_x(),
                  {"ph": "C", "pid": 0, "ts": 20, "name": "fabric",
                   "args": {"inflight": 3}},
                  {"ph": "i", "pid": 0, "tid": 1000000001, "ts": 30,
                   "name": "pick", "s": "t"}]
        r = self.run_check(self.path(doc(events)))
        self.assertEqual(r.returncode, 0, r.stdout + r.stderr)
        self.assertIn("OK", r.stdout)
        self.assertIn("3 event(s)", r.stdout)

    def test_truncated_json_is_an_error(self):
        r = self.run_check(self.path('{"traceEvents":[{"ph"'))
        self.assert_rejected(r, "not valid JSON")

    def test_missing_file_is_an_error(self):
        r = self.run_check(os.path.join(self.tmp.name, "nope.json"))
        self.assert_rejected(r, "cannot read")

    def test_non_object_top_level_is_an_error(self):
        self.assert_rejected(self.run_check(self.path([1, 2])), "top level")

    def test_missing_trace_events_is_an_error(self):
        self.assert_rejected(self.run_check(self.path({"otherData": {}})),
                             "'traceEvents'")

    def test_unknown_ph_is_an_error(self):
        bad = doc([{"ph": "Z", "pid": 0, "ts": 1, "name": "x"}])
        self.assert_rejected(self.run_check(self.path(bad)), "unknown ph")

    def test_slice_without_dur_is_an_error(self):
        bad = doc([{"ph": "X", "pid": 0, "ts": 1, "name": "coro"}])
        self.assert_rejected(self.run_check(self.path(bad)), "'dur'")

    def test_event_without_ts_is_an_error(self):
        bad = doc([{"ph": "i", "pid": 0, "name": "pick"}])
        self.assert_rejected(self.run_check(self.path(bad)), "'ts'")

    def test_event_without_pid_is_an_error(self):
        bad = doc([{"ph": "i", "ts": 1, "name": "pick"}])
        self.assert_rejected(self.run_check(self.path(bad)), "'pid'")

    def test_metadata_only_export_fails_the_floor(self):
        r = self.run_check(self.path(doc([meta()])))
        self.assert_rejected(r, "non-metadata")
        # ...and the floor is tunable for richer smokes.
        r = self.run_check(self.path(doc([meta(), slice_x()])), "--min-events", "5")
        self.assert_rejected(r, "at least 5")


if __name__ == "__main__":
    unittest.main(verbosity=2)
