#!/usr/bin/env python3
"""Bench regression gate for BENCH_sim.json.

Compares a freshly measured BENCH_sim.json against the committed
baseline and enforces a tolerance on simulated-MIPS throughput:

  * FAIL (exit 1) when any gated row regresses by more than --fail-pct
    (default 15%).
  * WARN (exit 0, annotated) when a gated row regresses by more than
    --warn-pct (default 5%).

Gated rows are the per-kernel decoded-interpreter measurements
(names ending in `/decoded`, `/decoded-fused` or `/decoded-unfused`
under `sim_mips/`): they are the simulator's product throughput. This
includes the per-fabric columns (`sim_mips/fabric/<label>/.../decoded`,
one per far-fabric backend), the per-cluster-size columns
(`sim_mips/cluster/<cores>c/.../decoded`, aggregate simulated MIPS of
an n-core shared-fabric run), the per-fault-intensity columns
(`sim_mips/faults/<spec>/.../decoded`, decoded MIPS with the
`sim::faults` retry/backoff machinery live on the fabric) and the
per-offered-load columns (`sim_mips/service/<spec>/.../decoded`, a
batch run plus the `sim::service` open-loop queueing replay at that
load) and the tracing columns (`sim_mips/trace/{off,on}/.../decoded`,
decoded MIPS with the `sim::trace` event ring off resp. on — the `off`
row is the zero-overhead canary), so a fabric model, cluster
interleave, fault decorator, service replay or tracer whose
bookkeeping drags
down decoded MIPS fails the same gate as any other kernel. The
sweep-store columns (`sim_mips/store/{cold,warm}/gups`) are
informational only (no gated suffix): `cold` prices simulate-and-persist,
`warm` prices serving the same matrix from disk. The `reference` rows are informational (the pre-change
baseline shape) and rows present on only one side are reported but
never gate — adding or renaming a kernel (or a whole fabric/cluster
group, against a baseline recorded before those subsystems existed)
must not break CI; such rows are
printed as `new row (not gated)` and start gating once a fresh baseline
containing them is committed.

Degenerate baselines never gate: a placeholder (no samples) or a
debug-mode recording against a release-mode measurement just prints a
notice and exits 0, so the first real measurement can land and become
the baseline (the CI workflow commits it).

Malformed inputs always fail: a file that is not valid JSON, whose top
level is not an object, or whose `samples` is not a list of objects is
an ERROR (exit 1) naming the file and the shape problem — a truncated
or corrupted BENCH_sim.json must never be mistaken for "no gated rows;
gate skipped".

Usage:
  python3 ci/check_bench_regression.py BASELINE.json FRESH.json \
      [--fail-pct 15] [--warn-pct 5]
"""

import argparse
import json
import sys

# Covers plain kernels (sim_mips/<bench>/<variant>/decoded), the fabric
# group (sim_mips/fabric/<label>/<bench>/decoded) and the cluster group
# (sim_mips/cluster/<cores>c/<bench>/decoded) alike.
GATED_SUFFIXES = ("/decoded", "/decoded-fused", "/decoded-unfused")


def load(path):
    """Parse one recording, validating its shape; exit 1 on malformed input."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        print(f"ERROR: {path} is not valid JSON: {e}")
        sys.exit(1)
    if not isinstance(doc, dict):
        print(f"ERROR: {path} is malformed: top level is "
              f"{type(doc).__name__}, expected an object")
        sys.exit(1)
    samples = doc.get("samples", [])
    if not isinstance(samples, list):
        print(f"ERROR: {path} is malformed: 'samples' is "
              f"{type(samples).__name__}, expected a list")
        sys.exit(1)
    for i, s in enumerate(samples):
        if not isinstance(s, dict):
            print(f"ERROR: {path} is malformed: samples[{i}] is "
                  f"{type(s).__name__}, expected an object")
            sys.exit(1)
    return doc


def rates(doc):
    """name -> simulated rate (instr/s) for rows that carry throughput."""
    out = {}
    for s in doc.get("samples", []):
        name, rate = s.get("name"), s.get("rate_per_s")
        if name and isinstance(rate, (int, float)) and rate > 0:
            out[name] = float(rate)
    return out


def gated(name):
    return name.startswith("sim_mips/") and name.endswith(GATED_SUFFIXES)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--fail-pct", type=float, default=15.0)
    ap.add_argument("--warn-pct", type=float, default=5.0)
    args = ap.parse_args()

    base_doc, fresh_doc = load(args.baseline), load(args.fresh)
    if fresh_doc is None:
        print(f"ERROR: fresh measurement {args.fresh} not found — did the bench step run?")
        return 1
    fresh = rates(fresh_doc)
    if not fresh:
        print(f"ERROR: fresh measurement {args.fresh} has no throughput samples")
        return 1

    if base_doc is None:
        print(f"NOTICE: no baseline at {args.baseline}; gate skipped")
        return 0
    base = rates(base_doc)
    if not base:
        print("NOTICE: baseline is a placeholder (no samples); gate skipped — "
              "the workflow records this run as the first measured baseline")
        return 0
    base_mode, fresh_mode = base_doc.get("mode"), fresh_doc.get("mode")
    if base_mode != fresh_mode:
        print(f"NOTICE: baseline mode '{base_mode}' != fresh mode '{fresh_mode}'; "
              "gate skipped (build profiles are not comparable)")
        return 0

    failures, warnings = [], []
    compared = 0
    for name in sorted(set(base) | set(fresh)):
        if name not in base:
            print(f"  new row (not gated):      {name}")
            continue
        if name not in fresh:
            print(f"  removed row (not gated):  {name}")
            continue
        b, f = base[name], fresh[name]
        delta = (f - b) / b * 100.0
        marker = " "
        if gated(name):
            compared += 1
            if delta < -args.fail_pct:
                failures.append((name, b, f, delta))
                marker = "F"
            elif delta < -args.warn_pct:
                warnings.append((name, b, f, delta))
                marker = "W"
        print(f"  [{marker}] {name}: {b / 1e6:.2f} -> {f / 1e6:.2f} simulated MIPS ({delta:+.1f}%)")

    for name, b, f, delta in warnings:
        print(f"::warning::bench regression >{args.warn_pct:.0f}%: {name} "
              f"{b / 1e6:.2f} -> {f / 1e6:.2f} MIPS ({delta:+.1f}%)")
    for name, b, f, delta in failures:
        print(f"::error::bench regression >{args.fail_pct:.0f}%: {name} "
              f"{b / 1e6:.2f} -> {f / 1e6:.2f} MIPS ({delta:+.1f}%)")

    if compared == 0:
        print("NOTICE: no gated rows in common; gate skipped")
        return 0
    if failures:
        print(f"FAIL: {len(failures)} kernel(s) regressed beyond {args.fail_pct:.0f}%")
        return 1
    print(f"OK: {compared} gated row(s) within tolerance "
          f"({len(warnings)} warning(s) past {args.warn_pct:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
